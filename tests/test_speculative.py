"""Speculative-decoding battery (DESIGN.md §9, serve/speculative.py).

The contract under test: ``ServeConfig(spec_k >= 1)`` is a pure
*throughput* knob — for every request, speculative outputs (greedy AND
temperature > 0) are bit-identical to the non-speculative engine and to a
solo ``Engine.generate`` call, across dense/paged x prefix on/off x int8-KV
on/off, across drafter quality (a full-depth drafter accepts everything; a
garbage drafter rejects everything), and across scheduler pressure (EOS
mid-window, slot recycling, preemption-with-recompute with a live draft
cache).

Also unit-covers the subsystem's pieces (split_chain / accept_window /
DraftModel / trim_request / complete_spec_window / worst_case_blocks), the
batched prefix-block copies (satellite: ``lm.copy_paged_blocks``), the
retrace budget (no recompiles after warmup), the kanlint drafter-cache
donation rule, and the CLI flag validation (invalid ``--spec-k`` => rc 2).

Property tests honor the ``tests/conftest.py`` hypothesis fallback shim.
"""

from __future__ import annotations

import textwrap

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.analysis import ast_rules
from repro.models import lm
from repro.serve import speculative as sp
from repro.serve.engine import Engine, ServeConfig
from repro.serve.kv_pool import BlockPool, blocks_for, worst_case_blocks
from repro.serve.scheduler import ContinuousScheduler
from repro.serve.speculative import DraftModel

from conftest import run_jax_subprocess

MAX_NEW = 6


@pytest.fixture(autouse=True, scope="module")
def _fresh_jax_caches():
    """This module runs near the end of the tier-1 suite, after hundreds of
    compiles have accumulated in-process; XLA's CPU backend has been seen
    to segfault on the NEXT compile in that state.  Start from clean
    compilation caches (earlier modules are already done; worst case a
    later reuse recompiles)."""
    import gc

    jax.clear_caches()
    gc.collect()
    yield

# lazy singletons (hypothesis fallback shim: no fixtures in property tests);
# engines are memoized per config because every Engine re-jits its programs
_ARCH = None
_PARAMS = None
_ENGINES: dict = {}


def arch_params():
    global _ARCH, _PARAMS
    if _ARCH is None:
        _ARCH = configs.get_reduced("kanformer-100m")
        _PARAMS = lm.init_params(jax.random.PRNGKey(0), _ARCH.model)
    return _ARCH, _PARAMS


def get_engine(spec_k=0, temp=0.0, paged=False, paged_read="shadow",
               prefix=True, pool_blocks=None, draft_layers=1,
               draft_quant=False, quant_kv=False, draft=None) -> Engine:
    key = (spec_k, temp, paged, paged_read, prefix, pool_blocks,
           draft_layers, draft_quant, quant_kv, id(draft))
    if key not in _ENGINES:
        arch, params = arch_params()
        model = arch.model
        if quant_kv:
            from repro.configs.common import enable_kv_quant
            model = enable_kv_quant(arch).model
        _ENGINES[key] = Engine(params, model, ServeConfig(
            max_seq=48, max_new_tokens=MAX_NEW, temperature=temp,
            paged=paged, block_size=8, pool_blocks=pool_blocks,
            paged_read=paged_read, prefix_caching=prefix,
            spec_k=spec_k, draft_layers=draft_layers,
            draft_quant=draft_quant, draft=draft,
        ))
    return _ENGINES[key]


RS = np.random.RandomState(11)
POOL = [RS.randint(1, 500, L).astype(np.int32) for L in (4, 5, 7, 9, 12, 14)]

_SOLO_MEMO: dict = {}


def solo(req: np.ndarray, rid: int, max_new: int, eos: int,
         temp: float = 0.0) -> np.ndarray:
    """Isolated single-request generation with the request's OWN sampling
    identity — the oracle every scheduling (speculative or not) must hit
    bit-for-bit."""
    key = (req.tobytes(), rid, max_new, eos, temp)
    if key not in _SOLO_MEMO:
        _SOLO_MEMO[key] = get_engine(temp=temp).generate(
            req[None].astype(np.int32), seed=0,
            request_ids=np.asarray([rid], np.int32),
            max_new=max_new, eos_id=eos,
        )[0]
    return _SOLO_MEMO[key]


def assert_matches_solo(outs, reqs, budgets=None, eos=-1, temp=0.0):
    budgets = budgets or [MAX_NEW] * len(reqs)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(
            solo(r, i, budgets[i], eos, temp), outs[i],
            err_msg=f"request {i} diverged from solo generate",
        )


# ---------------------------------------------------------------------------
# unit: PRNG chain splitting
# ---------------------------------------------------------------------------


def test_split_chain_matches_sequential_splits():
    keys = jax.vmap(jax.random.split)(
        jnp.stack([jax.random.PRNGKey(s) for s in (3, 7, 11)])
    )[:, 0]
    kts, chains = sp.split_chain(keys, 4)
    assert kts.shape == (3, 4, 2) and chains.shape == (3, 5, 2)
    # replay the sequential engine body split for split
    carry = keys
    for j in range(4):
        np.testing.assert_array_equal(np.asarray(chains[:, j]),
                                      np.asarray(carry))
        pairs = jax.vmap(jax.random.split)(carry)
        carry, kt = pairs[:, 0], pairs[:, 1]
        np.testing.assert_array_equal(np.asarray(kts[:, j]), np.asarray(kt))
    np.testing.assert_array_equal(np.asarray(chains[:, 4]), np.asarray(carry))


# ---------------------------------------------------------------------------
# unit: acceptance math
# ---------------------------------------------------------------------------


def test_accept_window_prefix_and_bonus():
    draft = jnp.asarray([[5, 6, 7], [5, 9, 7], [1, 2, 3]])
    target = jnp.asarray([[5, 6, 7, 8], [5, 6, 7, 8], [9, 9, 9, 9]])
    emitted, m, eos_new = sp.accept_window(
        draft, target, jnp.asarray([False] * 3), jnp.int32(-1), jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(m), [4, 2, 1])
    np.testing.assert_array_equal(
        np.asarray(emitted),
        [[5, 6, 7, 8], [5, 6, 0, 0], [9, 0, 0, 0]])
    assert not np.asarray(eos_new).any()


def test_accept_window_eos_truncates_and_latches():
    draft = jnp.asarray([[5, 77, 7]])
    target = jnp.asarray([[5, 77, 7, 8]])
    emitted, m, eos_new = sp.accept_window(
        draft, target, jnp.asarray([False]), jnp.int32(77), jnp.int32(0))
    # EOS at window position 1 is EMITTED, later accepted positions pad
    np.testing.assert_array_equal(np.asarray(emitted), [[5, 77, 0, 0]])
    np.testing.assert_array_equal(np.asarray(m), [2])
    assert bool(np.asarray(eos_new)[0])
    # eos at the bonus position: full window emits
    e2, m2, eos2 = sp.accept_window(
        jnp.asarray([[5, 6, 7]]), jnp.asarray([[5, 6, 7, 77]]),
        jnp.asarray([False]), jnp.int32(77), jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(m2), [4])
    assert bool(np.asarray(eos2)[0])


def test_accept_window_latched_row_emits_nothing():
    emitted, m, eos_new = sp.accept_window(
        jnp.asarray([[5, 6, 7]]), jnp.asarray([[5, 6, 7, 8]]),
        jnp.asarray([True]), jnp.int32(-1), jnp.int32(9))
    np.testing.assert_array_equal(np.asarray(emitted), [[9, 9, 9, 9]])
    np.testing.assert_array_equal(np.asarray(m), [0])
    assert bool(np.asarray(eos_new)[0])   # stays latched


# ---------------------------------------------------------------------------
# unit: DraftModel derivation
# ---------------------------------------------------------------------------


def test_draft_model_slices_unit_and_aliases_the_rest():
    arch, params = arch_params()
    d = DraftModel.from_target(params, arch.model, n_layers=1)
    assert d.cfg.n_repeats == 1 and arch.model.n_repeats == 2
    for blk_full, blk_draft in zip(params["unit"], d.params["unit"]):
        for a, b in zip(jax.tree.leaves(blk_full), jax.tree.leaves(blk_draft)):
            assert b.shape[0] == 1 and a.shape[1:] == b.shape[1:]
    # non-unit leaves are ALIASED, not copied (no extra HBM)
    assert d.params["embed"] is params["embed"]


def test_draft_model_validates_layers_and_arch():
    arch, params = arch_params()
    with pytest.raises(ValueError):
        DraftModel.from_target(params, arch.model, n_layers=0)
    with pytest.raises(ValueError):
        DraftModel.from_target(params, arch.model,
                               n_layers=arch.model.n_repeats + 1)


def test_draft_model_quant_roundtrips_without_touching_target():
    arch, params = arch_params()
    before = jax.tree.map(lambda a: np.asarray(a).copy(), params["unit"])
    d = DraftModel.from_target(params, arch.model, n_layers=1, quant=True)
    assert d.quant
    # target unit leaves untouched
    for blk_b, blk_p in zip(before, params["unit"]):
        for a, b in zip(jax.tree.leaves(blk_b), jax.tree.leaves(blk_p)):
            np.testing.assert_array_equal(a, np.asarray(b))
    # quantized drafter leaves take at most 255 distinct scaled levels per
    # output channel and stay within rounding error of the originals
    leaf = jax.tree.leaves(d.params["unit"][0])[0]
    src = jax.tree.leaves(params["unit"][0])[0][:1]
    err = np.abs(np.asarray(leaf, np.float32) - np.asarray(src, np.float32))
    scale = np.abs(np.asarray(src, np.float32)).max(axis=-1, keepdims=True)
    assert (err <= scale / 127.0 * 0.5 + 1e-7).all()


# ---------------------------------------------------------------------------
# unit: pool trim + worst-case bound + scheduler window accounting
# ---------------------------------------------------------------------------


def test_worst_case_blocks_spec_bound():
    # spec windows can write past the chunk bound: start at the last live
    # position and lay down spec_k drafts
    assert worst_case_blocks(4, 8, 4, 8, 64, spec_k=0) == \
        worst_case_blocks(4, 8, 4, 8, 64)
    assert worst_case_blocks(4, 8, 4, 8, 64, spec_k=3) == \
        blocks_for(4 + 8 - 1 + 3, 8)
    # clamped by max_seq like the chunk bound
    assert worst_case_blocks(4, 8, 4, 8, 16, spec_k=8) == blocks_for(16, 8)


def test_trim_request_releases_only_fresh_tail():
    pool = BlockPool(10, 8)
    got = pool.alloc(0, 5)
    freed = pool.trim_request(0, 2)
    assert freed == got[2:] and pool.owned_blocks(0) == got[:2]
    assert pool.free_count() == pool.usable - 2
    pool.release_request(0)
    pool.check_balanced(0)


def test_trim_request_refuses_shared_and_cached_blocks():
    pool = BlockPool(10, 8)
    blocks = pool.alloc(0, 2)
    pool.cache_ref(blocks[1])          # prefix cache holds the tail block
    with pytest.raises(AssertionError):
        pool.trim_request(0, 1)


def test_complete_spec_window_variable_emissions():
    sched = ContinuousScheduler(2, range(2))
    for b, rid in sched.admit_ready():
        sched.confirm_admit(b, rid, pos=4, remaining=5, eos_hit=False)
    out = sched.complete_spec_window(4, emitted_counts=[3, 7],
                                     eos_hits=[False, False])
    # row 0 keeps its 3 emissions; row 1 overshoots the budget: clamped to
    # remaining=5 and retired
    assert out == [(0, 0, 3, False), (1, 1, 5, True)]
    assert sched.table.slots[0].remaining == 2
    assert sched.total_token_steps == 8          # window capacity charged
    assert sched.useful_token_steps == 8         # 3 + 5 kept


# ---------------------------------------------------------------------------
# model-level: fused verify == sequential decode, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("quant_kv", [False, True])
def test_verify_window_logits_match_sequential_decode(quant_kv):
    """THE batch-axis invariance the whole acceptance rule stands on:
    scoring W window positions in one fused forward must produce bitwise
    the same logits as W sequential decode_step calls."""
    arch, params = arch_params()
    model = arch.model
    if quant_kv:
        from repro.configs.common import enable_kv_quant
        model = enable_kv_quant(arch).model
    toks = jnp.asarray(np.stack([POOL[2][:7], POOL[5][:7]]), jnp.int32)
    W = 4
    window = jnp.asarray(
        np.random.RandomState(5).randint(1, 500, (2, W)), jnp.int32)
    _, seq_caches = lm.prefill(params, model, {"tokens": toks}, 48,
                               jnp.float32)
    _, ver_caches = lm.prefill(params, model, {"tokens": toks}, 48,
                               jnp.float32)
    pos = jnp.asarray([7, 7], jnp.int32)
    seq_logits = []
    p = pos
    for j in range(W):
        lg, seq_caches = lm.decode_step(
            params, model, window[:, j:j + 1], seq_caches, p, jnp.float32)
        seq_logits.append(lg)
        p = p + 1
    ver_logits, ver_caches = lm.verify_window(
        params, model, window, ver_caches, pos, jnp.float32)
    assert ver_logits.shape == (2, W, model.vocab)
    for j in range(W):
        np.testing.assert_array_equal(
            np.asarray(seq_logits[j]), np.asarray(ver_logits[:, j]),
            err_msg=f"window position {j} diverged (quant_kv={quant_kv})")
    for a, b in zip(jax.tree.leaves(seq_caches), jax.tree.leaves(ver_caches)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# engine-level bit-identity (the acceptance sweep)
# ---------------------------------------------------------------------------


@hypothesis.settings(max_examples=6, deadline=None)
@hypothesis.given(
    order_seed=st.integers(0, 10_000),
    n_requests=st.integers(1, 5),
    slots=st.integers(1, 3),
    spec_k=st.integers(1, 3),
    paged=st.booleans(),
    paged_read=st.sampled_from(["shadow", "step"]),
    prefix=st.booleans(),
    temp=st.sampled_from([0.0, 0.7]),
    eos_pos=st.integers(-1, MAX_NEW - 1),
    budget_seed=st.integers(0, 10_000),
)
def test_property_speculative_bit_identity(order_seed, n_requests, slots,
                                           spec_k, paged, paged_read, prefix,
                                           temp, eos_pos, budget_seed):
    """Random request sets x random (spec_k, dense/paged, shadow/step,
    prefix on/off, greedy/sampled, EOS placement, budgets): every output is
    bit-identical to the isolated non-speculative generation, and the paged
    pool drains balanced."""
    rs = np.random.RandomState(order_seed)
    reqs = [POOL[rs.randint(len(POOL))] for _ in range(n_requests)]
    bs = np.random.RandomState(budget_seed)
    budgets = [int(bs.randint(1, MAX_NEW + 1)) for _ in range(n_requests)]
    if eos_pos >= 0:
        probe = solo(reqs[0], 0, MAX_NEW, -1, temp)
        eos = int(probe[min(eos_pos, budgets[0] - 1)])
    else:
        eos = -1
    eng = get_engine(spec_k=spec_k, temp=temp, paged=paged,
                     paged_read=paged_read, prefix=prefix)
    old = eng.cfg.eos_id
    eng.cfg.eos_id = eos               # traced arg — no retrace
    try:
        outs = eng.serve_continuous(reqs, slots=slots, chunk_steps=4,
                                    seed=0, max_new=budgets)
    finally:
        eng.cfg.eos_id = old
    assert eng.last_serve_stats["n_served"] == n_requests
    stats = eng.last_serve_stats["spec"]
    assert 0.0 <= stats["acceptance_rate"] <= 1.0
    assert stats["spec_k"] == spec_k
    assert_matches_solo(outs, reqs, budgets, eos, temp)
    if paged:
        eng._last_pool.check_balanced(0)


def test_speculative_matches_non_speculative_engine():
    """spec_k is a pure throughput knob: same outputs as the spec_k=0
    continuous engine under the same scheduling shape."""
    reqs = [POOL[0], POOL[2], POOL[5], POOL[1], POOL[3]]
    base = get_engine().serve_continuous(reqs, slots=2, chunk_steps=3, seed=0)
    outs = get_engine(spec_k=2).serve_continuous(
        reqs, slots=2, chunk_steps=3, seed=0)
    for a, b in zip(base, outs):
        np.testing.assert_array_equal(a, b)


def test_full_depth_drafter_accepts_everything():
    """draft_layers == n_repeats makes the drafter the target: every draft
    matches its verified token, so acceptance is exactly 1.0 (and the
    emitted stream is still the target chain's)."""
    arch, _ = arch_params()
    for temp in (0.0, 0.7):
        eng = get_engine(spec_k=2, temp=temp,
                         draft_layers=arch.model.n_repeats)
        outs = eng.serve_continuous(list(POOL), slots=3, chunk_steps=4,
                                    seed=0)
        assert eng.last_serve_stats["spec"]["acceptance_rate"] == 1.0
        assert_matches_solo(outs, POOL, temp=temp)


def _zero_drafter() -> DraftModel:
    arch, params = arch_params()
    d = DraftModel.from_target(params, arch.model, n_layers=1)
    dparams = dict(d.params)
    dparams["embed"] = {"table": jnp.zeros_like(params["embed"]["table"])}
    return DraftModel(params=dparams, cfg=d.cfg, n_layers=1)


_ZERO_DRAFTER = None


def test_garbage_drafter_rejects_everything_but_stays_exact():
    """The worst-case drafter (all-zero logits proposes token 0 forever):
    every draft is rejected, every window emits exactly one bonus token,
    the paged trim rolls back the whole rejected span each window — and
    outputs still match solo bit for bit."""
    global _ZERO_DRAFTER
    if _ZERO_DRAFTER is None:
        _ZERO_DRAFTER = _zero_drafter()
    for paged in (False, True):
        eng = get_engine(spec_k=3, paged=paged, draft=_ZERO_DRAFTER)
        outs = eng.serve_continuous(list(POOL), slots=3, chunk_steps=4,
                                    seed=0)
        stats = eng.last_serve_stats["spec"]
        assert stats["acceptance_rate"] == 0.0
        # admission prefill emits each request's first token; the remaining
        # budget is all window emissions, one bonus token per window
        assert stats["emitted_tokens"] == (MAX_NEW - 1) * len(POOL)
        assert_matches_solo(outs, POOL)
        if paged:
            eng._last_pool.check_balanced(0)


def test_quant_kv_speculative_matches_quant_solo():
    """int8 KV quant target: the window write-then-dequantized-attend path
    must reproduce the sequential quantized decode bitwise."""
    reqs = [POOL[0], POOL[3], POOL[4], POOL[5]]
    qsolo = get_engine(quant_kv=True).generate(
        np.stack([np.pad(r, (0, 14 - len(r))) for r in reqs]).astype(np.int32),
        seed=0, lengths=np.asarray([len(r) for r in reqs], np.int32),
        request_ids=np.arange(len(reqs), dtype=np.int32),
    )
    for paged in (False, True):
        eng = get_engine(spec_k=2, paged=paged, quant_kv=True)
        outs = eng.serve_continuous(reqs, slots=2, chunk_steps=4, seed=0)
        for i in range(len(reqs)):
            np.testing.assert_array_equal(qsolo[i], outs[i])


# ---------------------------------------------------------------------------
# scheduler edge cases the draft loop stresses (ISSUE satellite)
# ---------------------------------------------------------------------------


def test_eos_mid_draft_window_latches_and_pads():
    """EOS emitted inside a window: the row latches at the accepted
    position, the rest of the window pads, and the final output equals the
    sequential EOS semantics exactly."""
    probe = solo(POOL[2], 0, MAX_NEW, -1)
    eos = int(probe[2])                # fires mid-stream, mid-window
    eng = get_engine(spec_k=3)
    old = eng.cfg.eos_id
    eng.cfg.eos_id = eos
    try:
        outs = eng.serve_continuous([POOL[2], POOL[4], POOL[0]],
                                    slots=3, chunk_steps=4, seed=0)
    finally:
        eng.cfg.eos_id = old
    assert_matches_solo(outs, [POOL[2], POOL[4], POOL[0]], eos=eos)


def test_slot_recycled_between_windows():
    """More requests than slots + tiny budgets: slots recycle constantly,
    each admission must re-seed BOTH the target and drafter cache rows
    (lockstep across recycling)."""
    reqs = [POOL[i % len(POOL)] for i in range(7)]
    budgets = [2, 5, 1, 6, 3, 2, 4]
    eng = get_engine(spec_k=2)
    outs = eng.serve_continuous(reqs, slots=2, chunk_steps=4, seed=0,
                                max_new=budgets)
    assert_matches_solo(outs, reqs, budgets)


def test_preemption_with_live_draft_cache():
    """Pool sized to force preempt-youngest while drafts are in flight:
    the preempted request restarts from scratch (target AND drafter rows
    re-prefilled) and still produces the identical stream."""
    reqs = [POOL[i % len(POOL)] for i in range(8)]
    eng = get_engine(spec_k=3, paged=True, pool_blocks=8)
    outs = eng.serve_continuous(reqs, slots=4, chunk_steps=4, seed=0)
    assert eng.last_serve_stats["n_preemptions"] > 0, (
        "pool was not tight enough to force preemption — shrink pool_blocks")
    assert_matches_solo(outs, reqs)
    eng._last_pool.check_balanced(0)


# ---------------------------------------------------------------------------
# retrace budget: speculative serving compiles a fixed program set
# ---------------------------------------------------------------------------


def test_speculative_retrace_budget_no_programs_after_warmup():
    eng = get_engine(spec_k=2, paged=True)
    reqs = [POOL[0], POOL[2], POOL[5], POOL[3]]
    eng.serve_continuous(reqs, slots=2, chunk_steps=4, seed=0)
    warm = {n: s["programs"]
            for n, s in eng.compiles.snapshot().items()}
    assert warm.get("draft_chunk", 0) >= 1
    assert warm.get("verify_window", 0) >= 1
    assert warm.get("draft_prefill", 0) >= 1
    eng.serve_continuous(reqs, slots=2, chunk_steps=4, seed=0)
    after = {n: s["programs"] for n, s in eng.compiles.snapshot().items()}
    retraced = {n: after[n] - warm.get(n, 0)
                for n in after if after[n] != warm.get(n, 0)}
    assert retraced == {}, f"programs_after_warmup: {retraced}"


# ---------------------------------------------------------------------------
# satellite: batched prefix-block copies
# ---------------------------------------------------------------------------


def test_copy_paged_blocks_matches_sequential_singles():
    arch, params = arch_params()
    caches_a = lm.init_paged_caches(arch.model, 12, 8, jnp.float32)
    # fill with recognizable values
    caches_a = jax.tree.map(
        lambda a: jnp.arange(a.size, dtype=a.dtype).reshape(a.shape)
        if jnp.issubdtype(a.dtype, jnp.floating) else a, caches_a)
    caches_b = jax.tree.map(lambda a: a, caches_a)
    srcs, dsts = [1, 3, 5], [7, 8, 9]
    out_a = lm.copy_paged_blocks(caches_a, srcs, dsts)
    out_b = caches_b
    for s, d in zip(srcs, dsts):
        out_b = lm.copy_paged_block(out_b, s, d)
    for a, b in zip(jax.tree.leaves(out_a), jax.tree.leaves(out_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# satellite: kanlint covers the drafter-cache donation pattern
# ---------------------------------------------------------------------------


def _lint(src: str):
    return ast_rules.lint_source(textwrap.dedent(src),
                                 "src/repro/serve/x.py")


def test_kl101_flags_undonated_draft_caches():
    fs = _lint("""
        import jax
        step = jax.jit(lambda dparams, draft_caches: draft_caches)
    """)
    assert sorted(f.rule for f in fs) == ["KL101"]
    assert "draft_caches" in fs[0].message


def test_kl101_draft_caches_donation_satisfies():
    fs = _lint("""
        import jax
        step = jax.jit(lambda dparams, draft_caches: draft_caches,
                       donate_argnums=(1,))
    """)
    assert fs == []


# ---------------------------------------------------------------------------
# satellite: CLI flag validation (subprocess; invalid spec-k => rc 2)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("argv", [
    ["-m", "repro.launch.serve", "--arch", "kanformer-100m",
     "--engine", "continuous", "--spec-k", "-1"],
    ["-m", "repro.launch.serve", "--arch", "kanformer-100m",
     "--engine", "static", "--spec-k", "2"],
    ["-m", "repro.launch.serve", "--arch", "kanformer-100m",
     "--engine", "continuous", "--spec-k", "2", "--draft-layers", "99"],
    ["examples/serve_kan.py", "--spec-k", "-1"],
    ["examples/serve_kan.py", "--engine", "static", "--spec-k", "2"],
])
def test_cli_invalid_spec_flags_exit_2(argv):
    res = run_jax_subprocess(argv=argv)
    assert res.returncode == 2, (res.returncode, res.stderr[-500:])


def test_engine_rejects_negative_spec_k():
    arch, params = arch_params()
    with pytest.raises(ValueError):
        Engine(params, arch.model,
               ServeConfig(max_seq=48, max_new_tokens=4, spec_k=-1))
