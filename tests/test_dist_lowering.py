"""Distributed lowering integration tests.

Runs in a SUBPROCESS with 8 fake host devices (XLA_FLAGS must be set before
jax initialises — exactly the dry-run pattern) and lowers reduced configs on
a (2, 2, 2) pod/data/model mesh: proves the sharding rules produce valid,
divisible PartitionSpecs and the train/prefill/decode graphs compile with
collectives.
"""

import textwrap

from conftest import run_jax_subprocess

SCRIPT = textwrap.dedent(
    """
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import configs
    from repro.dist import sharding as SH
    from repro.models import lm
    from repro.optim import adamw
    from repro.train import step as train_step_lib

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    for arch_name in ["qwen2.5-3b", "olmoe-1b-7b", "zamba2-1.2b",
                      "deepseek-v2-lite", "xlstm-1.3b", "kanformer-100m"]:
        arch = configs.get_reduced(arch_name)
        model = arch.model
        axes = lm.param_axes(model)
        absp = lm.abstract_params(model)
        psh = SH.tree_shardings(axes, absp, mesh)
        params_in = SH.with_sharded_leaves(absp, psh)
        B, S = 8, 16
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32,
            sharding=NamedSharding(mesh, P(("pod", "data"), None)))
        if model.input_kind == "tokens":
            inputs = {"tokens": tokens, "labels": tokens}
        elif model.input_kind == "embeddings":
            inputs = {"embeddings": jax.ShapeDtypeStruct((B, S, model.d_model),
                jnp.bfloat16, sharding=NamedSharding(mesh, P(("pod","data"), None, None))),
                "labels": tokens}
        else:
            tt = S - model.n_prefix
            tok2 = jax.ShapeDtypeStruct((B, tt), jnp.int32,
                sharding=NamedSharding(mesh, P(("pod", "data"), None)))
            inputs = {"prefix_embeddings": jax.ShapeDtypeStruct(
                (B, model.n_prefix, model.d_model), jnp.bfloat16,
                sharding=NamedSharding(mesh, P(("pod","data"), None, None))),
                "tokens": tok2, "labels": tok2}
        # train step
        tstep = train_step_lib.make_train_step(
            model, adamw.AdamWConfig(), compute_dtype=jnp.bfloat16, accum_steps=2)
        abs_opt = jax.eval_shape(adamw.init_state, absp)
        osh = {"m": SH.tree_zero_shardings(axes, absp, mesh),
               "v": SH.tree_zero_shardings(axes, absp, mesh),
               "step": NamedSharding(mesh, P())}
        opt_in = SH.with_sharded_leaves(abs_opt, osh)
        with mesh:
            c = jax.jit(tstep, out_shardings=(psh, osh, None)).lower(
                params_in, opt_in, inputs).compile()
            ca = c.cost_analysis()
            ca = ca[0] if isinstance(ca, (list, tuple)) else ca
            assert ca["flops"] > 0
            # decode step
            cax = lm.cache_axes(model)
            absc = lm.abstract_caches(model, B, S, jnp.bfloat16)
            csh = SH.tree_shardings(cax, absc, mesh)
            caches_in = SH.with_sharded_leaves(absc, csh)
            if model.input_kind == "embeddings":
                tok1 = jax.ShapeDtypeStruct((B, 1, model.d_model), jnp.bfloat16,
                    sharding=NamedSharding(mesh, P(("pod","data"), None, None)))
            else:
                tok1 = jax.ShapeDtypeStruct((B, 1), jnp.int32,
                    sharding=NamedSharding(mesh, P(("pod", "data"), None)))
            pos = jax.ShapeDtypeStruct((B,), jnp.int32,
                sharding=NamedSharding(mesh, P(("pod", "data"))))
            d = jax.jit(lambda p, t, cc, po: lm.decode_step(p, model, t, cc, po, jnp.bfloat16),
                        out_shardings=(None, csh)).lower(
                params_in, tok1, caches_in, pos).compile()
        txt = c.as_text()
        has_coll = ("all-reduce" in txt) or ("all-gather" in txt) or ("reduce-scatter" in txt)
        assert has_coll, arch_name + ": no collectives in sharded train step?"
        print("OK", arch_name)
    print("ALL_OK")
    """
)


def test_multiaxis_lowering_subprocess():
    proc = run_jax_subprocess(SCRIPT, devices=8, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ALL_OK" in proc.stdout
