"""Pad-safe serving regression tests (PR acceptance criteria): a request's
generation is invariant to its batch-mates and to the amount of padding.

The engine right-pads mixed-length buckets and threads true per-request
lengths through ``generate``: causal attention never attends a pad, each
request samples from its own last real position, and ragged decode
overwrites pad cache slots before any mask exposes them.  The previous
revision left-padded with unmasked pads — outputs changed with bucket
composition (these tests fail against it).

The invariance now covers sampling too: per-row PRNG key chains are
derived from each request's *identity* (``request_ids``), never its batch
position, so ``temperature > 0`` draws are also batch-mate invariant (an
earlier revision drew all rows' noise from one batch-wide key).
"""

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serve.engine import Engine, ServeConfig


@pytest.fixture(scope="module")
def arch_params():
    arch = configs.get_reduced("qwen1.5-0.5b")
    params = lm.init_params(jax.random.PRNGKey(0), arch.model)
    return arch, params


@pytest.fixture(scope="module")
def engine(arch_params):
    arch, params = arch_params
    return Engine(params, arch.model, ServeConfig(max_seq=48, max_new_tokens=5))


@pytest.fixture(scope="module")
def sampled_engine(arch_params):
    arch, params = arch_params
    return Engine(params, arch.model,
                  ServeConfig(max_seq=48, max_new_tokens=5, temperature=1.0))


RS = np.random.RandomState(7)
REQ_SHORT = RS.randint(0, 100, 5).astype(np.int32)
REQ_MID = RS.randint(0, 100, 9).astype(np.int32)
REQ_LONG = RS.randint(0, 100, 14).astype(np.int32)


def test_generation_invariant_to_batch_mates(engine):
    """Same request, three different bucket compositions (and paddings):
    identical tokens out."""
    solo = engine.serve_requests([REQ_SHORT], batch_size=1)[0]
    with_mid = engine.serve_requests([REQ_SHORT, REQ_MID], batch_size=2)
    with_long = engine.serve_requests([REQ_LONG, REQ_SHORT, REQ_MID],
                                      batch_size=4)
    np.testing.assert_array_equal(solo, with_mid[0])
    np.testing.assert_array_equal(solo, with_long[1])
    # the longest request (never padded) also stays put
    np.testing.assert_array_equal(
        engine.serve_requests([REQ_LONG], batch_size=1)[0], with_long[0]
    )


def test_generation_invariant_to_padding_amount(engine):
    """Direct generate(): right-padding a prompt by any amount (with the
    true length threaded) reproduces the unpadded generation."""
    L = REQ_SHORT.shape[0]
    ref = engine.generate(REQ_SHORT[None, :].astype(np.int32), seed=0)
    for T in (L + 3, L + 9):
        padded = np.pad(REQ_SHORT, (0, T - L))[None, :].astype(np.int32)
        got = engine.generate(padded, seed=0, lengths=np.asarray([L]))
        np.testing.assert_array_equal(ref, got)


def test_ragged_batch_rows_match_solo(engine):
    """One mixed-length batch: every row equals its solo generation."""
    reqs = [REQ_SHORT, REQ_MID, REQ_LONG]
    T = max(r.shape[0] for r in reqs)
    padded = np.stack([np.pad(r, (0, T - r.shape[0])) for r in reqs]).astype(np.int32)
    lens = np.asarray([r.shape[0] for r in reqs], np.int32)
    batch = engine.generate(padded, seed=0, lengths=lens)
    for i, r in enumerate(reqs):
        solo = engine.generate(r[None, :].astype(np.int32), seed=0)
        np.testing.assert_array_equal(solo[0], batch[i])


def test_sampled_generation_invariant_to_batch_mates(sampled_engine):
    """temperature > 0: per-request PRNG keys (``request_ids``) make even
    the sampled draws independent of bucket composition and padding."""
    eng = sampled_engine
    solo = eng.generate(REQ_SHORT[None, :].astype(np.int32), seed=0,
                        request_ids=np.asarray([0]))
    T = max(len(REQ_SHORT), len(REQ_LONG))
    padded = np.stack([np.pad(REQ_SHORT, (0, T - len(REQ_SHORT))),
                       np.pad(REQ_LONG, (0, T - len(REQ_LONG)))]).astype(np.int32)
    both = eng.generate(padded, seed=0,
                        lengths=np.asarray([len(REQ_SHORT), len(REQ_LONG)]),
                        request_ids=np.asarray([0, 1]))
    np.testing.assert_array_equal(solo[0], both[0])
    # the serving drivers key rows by request index: same list position,
    # different batch-mates -> identical sampled output
    a = eng.serve_requests([REQ_SHORT, REQ_MID], batch_size=2, seed=0)
    b = eng.serve_requests([REQ_SHORT, REQ_LONG, REQ_MID], batch_size=4, seed=0)
    np.testing.assert_array_equal(a[0], b[0])
    c = eng.serve_continuous([REQ_SHORT, REQ_LONG], slots=2, chunk_steps=2,
                             seed=0)
    np.testing.assert_array_equal(solo[0], c[0])


def test_equal_length_bucket_keeps_sync_decode(engine):
    """Equal-length buckets take the scalar-position path (lengths=None) and
    stay identical to per-length generation."""
    reqs = [REQ_MID, RS.randint(0, 100, 9).astype(np.int32)]
    outs = engine.serve_requests(reqs, batch_size=2)
    solo = engine.generate(np.stack(reqs).astype(np.int32), seed=0)
    np.testing.assert_array_equal(outs[0], solo[0])
    np.testing.assert_array_equal(outs[1], solo[1])
