"""Pad-safe serving regression tests (PR acceptance criteria): a request's
generation is invariant to its batch-mates and to the amount of padding.

The engine right-pads mixed-length buckets and threads true per-request
lengths through ``generate``: causal attention never attends a pad, each
request samples from its own last real position, and ragged decode
overwrites pad cache slots before any mask exposes them.  The previous
revision left-padded with unmasked pads — outputs changed with bucket
composition (these tests fail against it).

The invariance guarantee is for greedy decoding (``temperature == 0``, the
engine default, used throughout here); with sampling the logits are still
pad-invariant but the noise is drawn from one batch-wide PRNG key, so
token draws depend on bucket composition (see the engine docstring).
"""

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serve.engine import Engine, ServeConfig


@pytest.fixture(scope="module")
def engine():
    arch = configs.get_reduced("qwen1.5-0.5b")
    params = lm.init_params(jax.random.PRNGKey(0), arch.model)
    return Engine(params, arch.model, ServeConfig(max_seq=48, max_new_tokens=5))


RS = np.random.RandomState(7)
REQ_SHORT = RS.randint(0, 100, 5).astype(np.int32)
REQ_MID = RS.randint(0, 100, 9).astype(np.int32)
REQ_LONG = RS.randint(0, 100, 14).astype(np.int32)


def test_generation_invariant_to_batch_mates(engine):
    """Same request, three different bucket compositions (and paddings):
    identical tokens out."""
    solo = engine.serve_requests([REQ_SHORT], batch_size=1)[0]
    with_mid = engine.serve_requests([REQ_SHORT, REQ_MID], batch_size=2)
    with_long = engine.serve_requests([REQ_LONG, REQ_SHORT, REQ_MID],
                                      batch_size=4)
    np.testing.assert_array_equal(solo, with_mid[0])
    np.testing.assert_array_equal(solo, with_long[1])
    # the longest request (never padded) also stays put
    np.testing.assert_array_equal(
        engine.serve_requests([REQ_LONG], batch_size=1)[0], with_long[0]
    )


def test_generation_invariant_to_padding_amount(engine):
    """Direct generate(): right-padding a prompt by any amount (with the
    true length threaded) reproduces the unpadded generation."""
    L = REQ_SHORT.shape[0]
    ref = engine.generate(REQ_SHORT[None, :].astype(np.int32), seed=0)
    for T in (L + 3, L + 9):
        padded = np.pad(REQ_SHORT, (0, T - L))[None, :].astype(np.int32)
        got = engine.generate(padded, seed=0, lengths=np.asarray([L]))
        np.testing.assert_array_equal(ref, got)


def test_ragged_batch_rows_match_solo(engine):
    """One mixed-length batch: every row equals its solo generation."""
    reqs = [REQ_SHORT, REQ_MID, REQ_LONG]
    T = max(r.shape[0] for r in reqs)
    padded = np.stack([np.pad(r, (0, T - r.shape[0])) for r in reqs]).astype(np.int32)
    lens = np.asarray([r.shape[0] for r in reqs], np.int32)
    batch = engine.generate(padded, seed=0, lengths=lens)
    for i, r in enumerate(reqs):
        solo = engine.generate(r[None, :].astype(np.int32), seed=0)
        np.testing.assert_array_equal(solo[0], batch[i])


def test_equal_length_bucket_keeps_sync_decode(engine):
    """Equal-length buckets take the scalar-position path (lengths=None) and
    stay identical to per-length generation."""
    reqs = [REQ_MID, RS.randint(0, 100, 9).astype(np.int32)]
    outs = engine.serve_requests(reqs, batch_size=2)
    solo = engine.generate(np.stack(reqs).astype(np.int32), seed=0)
    np.testing.assert_array_equal(outs[0], solo[0])
    np.testing.assert_array_equal(outs[1], solo[1])
