"""Chunked-parallel mLSTM (§Perf optimisation) ≡ recurrent baseline."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import xlstm as X
from repro.models.layers import ParamCtx


@pytest.mark.parametrize("T,chunk", [(64, 8), (128, 32), (96, 16)])
def test_chunked_equals_recurrent(T, chunk):
    cfg = X.XLSTMConfig(d_model=32, n_heads=2, chunk=chunk)
    params = X.mlstm_init(ParamCtx("init", jax.random.PRNGKey(0)), cfg)
    rs = np.random.RandomState(T)
    x = jnp.asarray(rs.normal(size=(3, T, 32)).astype(np.float32) * 0.5)
    y_rec, st_rec = X.mlstm_forward(params, cfg, x, return_state=True)
    cfg_c = dataclasses.replace(cfg, mlstm_impl="chunked")
    y_chk, st_chk = X.mlstm_forward(params, cfg_c, x, return_state=True)
    np.testing.assert_allclose(
        np.asarray(y_rec), np.asarray(y_chk), rtol=1e-4, atol=1e-5
    )
    for k in ("C", "n", "m"):
        np.testing.assert_allclose(
            np.asarray(st_rec[k]), np.asarray(st_chk[k]), rtol=1e-4, atol=1e-5
        )


def test_chunked_then_decode_continues():
    """Prefill with the chunked impl, continue with decode steps — the state
    handoff must be seamless (same semantics as recurrent)."""
    cfg = X.XLSTMConfig(d_model=16, n_heads=2, chunk=8, mlstm_impl="chunked")
    params = X.mlstm_init(ParamCtx("init", jax.random.PRNGKey(1)), cfg)
    rs = np.random.RandomState(5)
    x = jnp.asarray(rs.normal(size=(2, 24, 16)).astype(np.float32) * 0.5)
    y_full = X.mlstm_forward(params, cfg, x)
    _, st = X.mlstm_forward(params, cfg, x[:, :16], return_state=True)
    y = None
    for t in range(16, 24):
        y, st = X.mlstm_decode_step(params, cfg, x[:, t : t + 1], st)
    np.testing.assert_allclose(
        np.asarray(y[:, 0]), np.asarray(y_full[:, -1]), rtol=2e-3, atol=2e-4
    )
