"""kanlint battery: rule unit tests on synthetic sources + the acceptance
CLI checks.

Acceptance contract (ISSUE): ``python -m repro.analysis --check src`` exits
non-zero when seeded with a planted violation from each rule family —
missing donation (KL101), host readback (KL102), VMEM-overflow cache entry
(KL201), unpinned cache-mutating entry point (KL105) — and exits zero on
the fixed tree.  The CLI tests below run each plant through the real
subprocess entry points (``repro.analysis`` and ``repro.launch.lint``),
the unit tests drive the rule modules in-process.
"""

from __future__ import annotations

import json
import textwrap

import numpy as np
import pytest

from repro.analysis import ast_rules, findings, run_check, sharding_audit
from repro.analysis.retrace import RetraceRegistry, counting

from conftest import run_jax_subprocess


def lint(src: str, path: str = "src/repro/serve/x.py"):
    return ast_rules.lint_source(textwrap.dedent(src), path)


def rules_of(fs) -> list[str]:
    return sorted(f.rule for f in fs)


# ---------------------------------------------------------------------------
# KL101 donation
# ---------------------------------------------------------------------------


def test_kl101_flags_undonated_cache_arg():
    fs = lint("""
        import jax
        step = jax.jit(lambda params, caches: caches)
    """)
    assert rules_of(fs) == ["KL101"]
    assert "caches" in fs[0].message and "donate_argnums" in fs[0].hint


def test_kl101_satisfied_by_donation_and_by_static():
    fs = lint("""
        import jax
        step = jax.jit(lambda params, caches: caches, donate_argnums=(1,))
        other = jax.jit(lambda params, tokens: tokens)
    """)
    assert fs == []


def test_kl101_sees_through_counting_wrapper():
    """The engine wraps every jitted callable in the retrace sentinel; the
    rule must resolve through it or it goes blind on its flagship target."""
    fs = lint("""
        import jax
        from repro.analysis.retrace import counting
        reg = object()
        step = jax.jit(counting(lambda params, caches: caches, "s", reg))
    """)
    assert rules_of(fs) == ["KL101"]


def test_kl101_pragma_waives_via_run_check_machinery():
    src = textwrap.dedent("""
        import jax
        step = jax.jit(   # kanlint: ignore[KL101]
            lambda params, caches: caches)
    """)
    fs = ast_rules.lint_source(src, "src/repro/serve/x.py")
    kept = findings.apply_pragmas(
        fs, {"src/repro/serve/x.py": findings.file_pragmas(src)})
    assert kept == []


def test_kl101_decorated_def_and_named_resolution():
    fs = lint("""
        import jax

        @jax.jit
        def step(params, pool):
            return pool

        def _impl(params, kv):
            return kv

        run = jax.jit(_impl)
    """)
    assert rules_of(fs) == ["KL101", "KL101"]


# ---------------------------------------------------------------------------
# KL102 host sync
# ---------------------------------------------------------------------------


def test_kl102_flags_readback_of_jitted_result():
    fs = lint("""
        import jax
        import numpy as np

        class E:
            def __init__(self):
                self._f = jax.jit(lambda x: x)

            def go(self, x):
                y = self._f(x)
                z = np.asarray(y)
                return z
    """)
    assert rules_of(fs) == ["KL102"]
    assert "device_get" in fs[0].hint


def test_kl102_device_get_is_sanctioned_and_returns_exempt():
    fs = lint("""
        import jax
        import numpy as np

        class E:
            def __init__(self):
                self._f = jax.jit(lambda x: x)

            def batched(self, x):
                y = self._f(x)
                a, b = jax.device_get((y, y))
                return np.asarray(y)
    """)
    assert fs == []


# ---------------------------------------------------------------------------
# KL103 float64 / KL104 purity
# ---------------------------------------------------------------------------


def test_kl103_float64_on_device_path_only():
    src = """
        import jax.numpy as jnp
        x = jnp.zeros((4,), dtype=jnp.float64)
    """
    assert rules_of(lint(src, "src/repro/models/x.py")) == ["KL103"]
    assert lint(src, "benchmarks/x.py") == []


def test_kl104_impure_calls_in_traced_function():
    fs = lint("""
        import jax
        import numpy as np
        import time

        @jax.jit
        def step(x):
            t = time.time()
            r = np.random.rand()
            return x + t + r

        def host_side():
            return time.time()
    """, path="benchmarks/x.py")
    assert rules_of(fs) == ["KL104", "KL104"]


# ---------------------------------------------------------------------------
# KL105 sharding audit
# ---------------------------------------------------------------------------


def test_kl105_public_cache_entry_point_needs_shard():
    src = textwrap.dedent("""
        def decode_step(params, tok, cache, pos):
            return cache

        def _private(params, cache):
            return cache

        def shard_ok(params, cache, shard=None):
            return cache
    """)
    fs = sharding_audit.audit_source(src, "src/repro/models/x.py")
    assert rules_of(fs) == ["KL105"]
    assert "decode_step" in fs[0].message
    # non-model paths are out of audit scope
    assert sharding_audit.audit_source(src, "src/repro/serve/x.py") == []


# ---------------------------------------------------------------------------
# KL2xx kernel-config validator
# ---------------------------------------------------------------------------


def test_kl201_vmem_overflow_and_contraction():
    from repro.analysis import kernel_configs as kc

    fs = kc.validate_tiles("fused", (256, 4096, 512), 256, 512, 4096, 8,
                           "float32", "tpu", None, origin="unit test")
    assert "KL201" in rules_of(fs)


def test_kl202_alignment_tpu_only():
    from repro.analysis import kernel_configs as kc

    fs = kc.validate_tiles("fused", (12, 64, 8), 64, 64, 128, 8,
                           "float32", "tpu", None, origin="unit test")
    assert "KL202" in rules_of(fs)
    fs_cpu = kc.validate_tiles("fused", (12, 64, 8), 64, 64, 128, 8,
                               "float32", "cpu", None, origin="unit test")
    assert "KL202" not in rules_of(fs_cpu)


def test_kl203_oversized_tile():
    from repro.analysis import kernel_configs as kc

    fs = kc.validate_tiles("fused", (512, 64, 8), 64, 64, 128, 8,
                           "float32", "cpu", None, origin="unit test")
    assert "KL203" in rules_of(fs)


def test_shipped_candidate_spaces_and_defaults_are_clean(monkeypatch):
    """The repo's own autotuner tables must validate — this is the static
    half of the acceptance criterion (point the cache env at nothing so a
    developer's local cache can't leak into the assertion)."""
    from repro.analysis import kernel_configs as kc
    from repro.kernels import autotune as tune

    monkeypatch.setenv(tune.CACHE_ENV, "/nonexistent/autotune.json")
    assert kc.validate_all() == []


# ---------------------------------------------------------------------------
# retrace sentinel unit
# ---------------------------------------------------------------------------


def test_retrace_registry_counts_distinct_abstract_signatures():
    import jax
    import jax.numpy as jnp

    reg = RetraceRegistry()
    f = jax.jit(counting(lambda x: x + 1, "f", reg))
    f(jnp.zeros((2,)))
    f(jnp.ones((2,)))              # same abstract signature: cache hit
    f(jnp.zeros((3,)))             # new shape: one more program
    snap = reg.snapshot()
    assert snap["f"]["programs"] == 2
    assert snap["f"]["traces"] == 2
    assert reg.programs("f") == 2
    out = np.asarray(f(jnp.zeros((2,))))
    np.testing.assert_array_equal(out, np.ones((2,)))
    assert reg.snapshot()["f"]["programs"] == 2   # still no retrace


# ---------------------------------------------------------------------------
# baseline machinery
# ---------------------------------------------------------------------------


def test_baseline_round_trip_is_line_independent(tmp_path):
    f1 = findings.Finding("KL101", "a.py", 10, "jit without donation", "fix")
    path = tmp_path / "base.json"
    findings.save_baseline(str(path), [f1])
    base = findings.load_baseline(str(path))
    moved = findings.Finding("KL101", "a.py", 99, "jit without donation", "fix")
    new, old = findings.split_baselined([moved], base)
    assert new == [] and old == [moved]
    assert findings.load_baseline(str(tmp_path / "missing.json")) == set()


# ---------------------------------------------------------------------------
# acceptance: CLI plants (subprocess, real entry points)
# ---------------------------------------------------------------------------

PLANTS = {
    "KL101": """
        import jax
        step = jax.jit(lambda params, caches: caches)
    """,
    "KL102": """
        import jax
        import numpy as np

        class E:
            def __init__(self):
                self._f = jax.jit(lambda x: x)

            def go(self, x):
                y = self._f(x)
                z = np.asarray(y)
                return z
    """,
    "KL105": """
        def decode_step(params, tok, cache, pos):
            return cache
    """,
}


def _check_cli(paths, tmp_path, extra=None, env_extra=None, module="repro.analysis"):
    argv = ["-m", module, "--check"] if module == "repro.analysis" else ["-m", module]
    argv += list(paths) + ["--baseline", str(tmp_path / "b.json")]
    argv += list(extra or [])
    return run_jax_subprocess(argv=argv, env_extra=env_extra)


@pytest.mark.parametrize("rule", sorted(PLANTS))
def test_cli_planted_violation_fails_check(rule, tmp_path):
    sub = "models" if rule == "KL105" else "serve"
    d = tmp_path / sub
    d.mkdir()
    (d / "planted.py").write_text(textwrap.dedent(PLANTS[rule]))
    r = _check_cli([str(d)], tmp_path, extra=["--no-kernel-validator"])
    assert r.returncode == 1, r.stdout + r.stderr
    assert rule in r.stdout


def test_cli_planted_vmem_cache_entry_fails_check(tmp_path):
    """KL201 plant: a hand-edited measurement-cache winner that fits the
    grid but oversubscribes VMEM (and the contraction budget) on TPU."""
    cache = tmp_path / "autotune.json"
    key = "fused|BS=256|K=512|N=4096|M=8|dtype=float32|backend=tpu"
    cache.write_text(json.dumps({key: {"tiles": [256, 4096, 512], "us": 1.0}}))
    empty = tmp_path / "empty"
    empty.mkdir()
    r = _check_cli([str(empty)], tmp_path,
                   env_extra={"KAN_SAS_AUTOTUNE_CACHE": str(cache)})
    assert r.returncode == 1, r.stdout + r.stderr
    assert "KL201" in r.stdout


def test_cli_fixed_tree_is_clean(tmp_path):
    """The acceptance zero: the repo's own ``src`` tree has no new findings
    (the checked-in baseline is empty, so nothing hides there either)."""
    r = run_jax_subprocess(
        argv=["-m", "repro.analysis", "--check", "src"],
        env_extra={"KAN_SAS_AUTOTUNE_CACHE": str(tmp_path / "no-cache.json")},
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 new finding(s)" in r.stdout
    assert json.load(open("kanlint.baseline.json"))["findings"] == []


def test_cli_update_baseline_round_trip(tmp_path):
    d = tmp_path / "serve"
    d.mkdir()
    (d / "planted.py").write_text(textwrap.dedent(PLANTS["KL101"]))
    r1 = _check_cli([str(d)], tmp_path,
                    extra=["--no-kernel-validator", "--update-baseline"])
    assert r1.returncode == 0 and "baseline updated" in r1.stdout
    r2 = _check_cli([str(d)], tmp_path, extra=["--no-kernel-validator"])
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "1 baselined" in r2.stdout


def test_launch_lint_cli_smoke(tmp_path):
    """Launcher front door: per-rule summary + the same exit contract."""
    d = tmp_path / "serve"
    d.mkdir()
    (d / "clean.py").write_text("x = 1\n")
    r = _check_cli([str(d)], tmp_path, extra=["--no-kernel-validator"],
                   module="repro.launch.lint")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "[lint] scanned 1 files: 0 new finding(s) (none)" in r.stdout
    (d / "planted.py").write_text(textwrap.dedent(PLANTS["KL101"]))
    r = _check_cli([str(d)], tmp_path, extra=["--no-kernel-validator"],
                   module="repro.launch.lint")
    assert r.returncode == 1
    assert "KL101=1" in r.stdout


def test_run_check_in_process_matches_cli_contract(tmp_path, monkeypatch):
    """run_check is the single engine under both CLIs."""
    from repro.kernels import autotune as tune

    monkeypatch.setenv(tune.CACHE_ENV, str(tmp_path / "no-cache.json"))
    d = tmp_path / "serve"
    d.mkdir()
    (d / "planted.py").write_text(textwrap.dedent(PLANTS["KL101"]))
    rep = run_check([str(d)], baseline_path=str(tmp_path / "b.json"))
    assert [f.rule for f in rep["new"]] == ["KL101"]
    assert rep["files"] == 1 and rep["baselined"] == []
