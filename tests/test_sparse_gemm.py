"""Sparse N:M kernel tests (PR acceptance criteria):

* ``kan_sparse_gemm`` matches the fused path / dense oracle within dtype
  tolerance on ragged (non-tile-multiple) shapes, fp32 and bf16, with and
  without the base term — one ``pallas_call`` per layer;
* the sparse int8 kernel is bit-identical to the dense-band int8 kernel;
* ``resolve_inference_method`` picks sparse at decode row counts on TPU;
* the autotuner knows the sparse kernels (per-kernel candidate spaces) and
  the cache survives corruption, mutation, and concurrent writers.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kan_layer as kl
from repro.core import quantization as q
from repro.core.bspline import SplineGrid


def _layer(G, P, K, N, seed=0, base=True, dtype=jnp.float32):
    g = SplineGrid(-1.0, 1.0, G, P)
    cfg = kl.KANLayerConfig(K, N, g, base=base)
    params = kl.init_kan_layer(jax.random.PRNGKey(seed), cfg, dtype)
    return g, params


class TestSparseMatchesFused:
    # ragged shapes on purpose (the kernel pads internally); includes the
    # decode shapes (BS <= 8) the kernel is for
    SHAPES = [(5, 3, 40, 24, 1), (5, 3, 40, 24, 8), (5, 3, 100, 37, 5),
              (3, 2, 33, 5, 7), (10, 3, 17, 20, 3), (3, 3, 1, 22, 9),
              (2, 1, 9, 11, 16)]

    @pytest.mark.parametrize("G,P,K,N,BS", SHAPES)
    def test_sparse_matches_dense_fp32(self, G, P, K, N, BS):
        g, params = _layer(G, P, K, N)
        x = jnp.asarray(
            np.random.RandomState(BS + K).uniform(-1, 1, (BS, K)).astype(np.float32)
        )
        a = kl.kan_layer_apply(params, x, g, "dense")
        b = kl.kan_layer_apply(params, x, g, "sparse")
        c = kl.kan_layer_apply(params, x, g, "fused")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
        # sparse vs fused: same basis values, same fp32 accumulation — the
        # two kernels differ only in skipping the zero MACs
        np.testing.assert_allclose(np.asarray(b), np.asarray(c),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("G,P,K,N,BS", SHAPES[:3])
    def test_sparse_matches_dense_bf16(self, G, P, K, N, BS):
        g, params = _layer(G, P, K, N)
        x32 = jnp.asarray(
            np.random.RandomState(BS).uniform(-1, 1, (BS, K)).astype(np.float32)
        )
        ref = kl.kan_layer_apply(params, x32, g, "dense")
        p16 = jax.tree.map(lambda a: a.astype(jnp.bfloat16), params)
        got = kl.kan_layer_apply(p16, x32.astype(jnp.bfloat16), g, "sparse")
        scale = float(jnp.abs(ref).max()) + 1e-9
        err = float(jnp.abs(got.astype(jnp.float32) - ref).max()) / scale
        assert err < 2e-2, err

    def test_sparse_without_base(self):
        g, params = _layer(5, 3, 24, 16, base=False)
        assert "base_w" not in params
        x = jnp.asarray(
            np.random.RandomState(1).uniform(-1, 1, (6, 24)).astype(np.float32)
        )
        a = kl.kan_layer_apply(params, x, g, "dense")
        b = kl.kan_layer_apply(params, x, g, "sparse")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)

    def test_single_pallas_call(self):
        """Spline + base in ONE kernel for the sparse datapath too."""
        g, params = _layer(5, 3, 24, 16)
        x = jnp.zeros((8, 24), jnp.float32)
        jaxpr = str(jax.make_jaxpr(
            lambda p, x: kl.kan_layer_apply(p, x, g, "sparse")
        )(params, x))
        assert jaxpr.count("pallas_call") == 1, jaxpr.count("pallas_call")

    def test_explicit_tiles_win(self):
        """Pinned bb/bn/bk bypass the autotuner (kernel unit-test contract)."""
        from repro.kernels import ops as kops

        g, params = _layer(5, 3, 16, 12)
        x = jnp.asarray(
            np.random.RandomState(2).uniform(-1, 1, (5, 16)).astype(np.float32)
        )
        a = kops.kan_sparse_gemm(x, params["coeff"], g,
                                 base_w=params["base_w"], bb=8, bn=8, bk=4)
        b = kl.kan_layer_apply(params, x, g, "dense")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


class TestSparseInt8:
    @pytest.mark.parametrize("G,P,BS,K,N", [(5, 3, 8, 24, 16),
                                            (5, 3, 33, 10, 7),
                                            (3, 2, 1, 5, 9)])
    def test_bit_identical_to_dense_band(self, G, P, BS, K, N):
        """Same integer address math, same ROM values, same int32
        accumulator — only the zero multiplies are skipped."""
        from repro.kernels import ops as kops

        g = SplineGrid(-1.0, 1.0, G, P)
        rs = np.random.RandomState(BS)
        x = jnp.asarray(rs.uniform(-1.4, 1.4, (BS, K)).astype(np.float32))
        qg = q.QuantizedGrid.make(g)
        x_q = qg.x_quant.quantize(x)
        lut_u8 = jnp.asarray(q.build_lut_u8(P, 256))
        cq = jnp.asarray(rs.randint(-127, 128, (K, g.n_basis, N)).astype(np.int8))
        a = kops.kan_int8_gemm(x_q, lut_u8, cq, g, bb=8, bn=8, bk=4)
        b = kops.kan_sparse_int8_gemm(x_q, lut_u8, cq, g, bb=8, bn=8, bk=4)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_fused_dequant_epilogue(self):
        """scale given: dequantised out_dtype emitted straight from the
        kernel, matching the dense-band kernel's epilogue."""
        from repro.kernels import ops as kops

        g = SplineGrid(-1.0, 1.0, 5, 3)
        rs = np.random.RandomState(0)
        x = jnp.asarray(rs.uniform(-1, 1, (4, 10)).astype(np.float32))
        qg = q.QuantizedGrid.make(g)
        x_q = qg.x_quant.quantize(x)
        lut_u8 = jnp.asarray(q.build_lut_u8(g.P, 256))
        cq = jnp.asarray(rs.randint(-127, 128, (10, g.n_basis, 6)).astype(np.int8))
        scale = jnp.asarray(rs.uniform(0.5, 2.0, (6,)).astype(np.float32))
        a = kops.kan_int8_gemm(x_q, lut_u8, cq, g, scale=scale,
                               bb=8, bn=8, bk=4, out_dtype=jnp.bfloat16)
        b = kops.kan_sparse_int8_gemm(x_q, lut_u8, cq, g, scale=scale,
                                      bb=8, bn=8, bk=4, out_dtype=jnp.bfloat16)
        assert b.dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestMethodResolution:
    def test_sparse_at_decode_rows_on_tpu(self):
        assert kl.resolve_inference_method("tpu", rows=1) == "sparse"
        assert kl.resolve_inference_method("tpu", rows=8) == "sparse"
        assert kl.resolve_inference_method("tpu", rows=9) == "fused"
        assert kl.resolve_inference_method("tpu") == "fused"
        assert kl.resolve_inference_method("cpu", rows=1) == "compact"

    def test_threshold_env_override(self, monkeypatch):
        monkeypatch.setenv("KAN_SAS_SPARSE_MAX_ROWS", "64")
        assert kl.resolve_inference_method("tpu", rows=64) == "sparse"
        monkeypatch.setenv("KAN_SAS_INFERENCE_METHOD", "fused")
        assert kl.resolve_inference_method("tpu", rows=1) == "fused"

    def test_auto_uses_row_count(self, monkeypatch):
        """kan_layer_apply('auto') resolves per flattened row count: decode
        row counts pick the sparse kernel when the backend heuristic says
        TPU (forced here via the env override)."""
        g, params = _layer(5, 3, 8, 6)
        x = jnp.zeros((4, 8), jnp.float32)
        y = kl.kan_layer_apply(params, x, g, "auto")   # cpu -> compact
        np.testing.assert_allclose(
            np.asarray(y),
            np.asarray(kl.kan_layer_apply(params, x, g, "dense")),
            atol=1e-5,
        )
        monkeypatch.setenv("KAN_SAS_INFERENCE_METHOD", "sparse")
        y2 = kl.kan_layer_apply(params, x, g, "auto")  # forced sparse kernel
        np.testing.assert_allclose(np.asarray(y2), np.asarray(y), atol=1e-5)


class TestAutotuneSparse:
    def test_sparse_candidate_space_wider_bk(self):
        from repro.kernels import autotune as tune

        dense = tune.candidate_tiles("fused", 8, 256, 256, 8, backend="cpu")
        sparse = tune.candidate_tiles("sparse", 8, 256, 256, 8,
                                      backend="cpu", nnz=4)
        assert max(bk for _, _, bk in dense) * 8 <= 1024
        assert max(bk for _, _, bk in sparse) * 4 <= 1024
        assert max(bk for _, _, bk in sparse) > max(bk for _, _, bk in dense)
        # sparse candidates are decode-shaped: batch tile stays small
        assert max(bb for bb, _, _ in sparse) <= 32

    def test_sparse_defaults_and_heuristic(self):
        from repro.kernels import autotune as tune

        bb, bn, bk = tune.get_tiles("sparse", 8, 256, 256, 8,
                                    jnp.float32, "cpu", nnz=4)
        assert bb <= 32 and bk * 4 <= 1024
        # tiny problems stay clamped
        bb, bn, bk = tune.get_tiles("sparse", 3, 5, 7, 8,
                                    jnp.float32, "cpu", nnz=4)
        assert bb <= 8 and bk <= 5
        # the decode-shaped DEFAULTS are clamped to the problem too: small
        # K must not pad to the table's bk, nor bn beyond N
        bb, bn, bk = tune.get_tiles("sparse", 8, 16, 128, 8,
                                    jnp.float32, "cpu", nnz=4)
        assert bk <= 16 and bn <= 128

    def test_corrupt_cache_falls_back(self, tmp_path, monkeypatch):
        from repro.kernels import autotune as tune

        path = tmp_path / "at.json"
        monkeypatch.setenv(tune.CACHE_ENV, str(path))
        path.write_text("{ this is not json")
        tiles = tune.get_tiles("fused", 64, 16, 32, 8, jnp.float32, "cpu")
        assert len(tiles) == 3 and all(t > 0 for t in tiles)
        # malformed entry schema also falls through to defaults
        key = tune.problem_key("fused", 64, 16, 32, 8, jnp.float32, "cpu")
        path.write_text(json.dumps({key: {"tiles": "nope"}}))
        tiles = tune.get_tiles("fused", 64, 16, 32, 8, jnp.float32, "cpu")
        assert len(tiles) == 3 and all(t > 0 for t in tiles)

    def test_load_cache_returns_copies(self, tmp_path, monkeypatch):
        from repro.kernels import autotune as tune

        monkeypatch.setenv(tune.CACHE_ENV, str(tmp_path / "at.json"))
        key = tune.problem_key("fused", 8, 8, 8, 8, jnp.float32, "cpu")
        tune._save_cache({key: {"tiles": [8, 8, 4], "us": 1.0}})
        first = tune._load_cache()
        first[key]["tiles"] = [999, 999, 999]   # mutate the returned dict
        first["junk"] = 1
        # a later reader must see the on-disk truth, not the mutation
        assert tune._load_cache()[key]["tiles"] == [8, 8, 4]
        assert "junk" not in tune._load_cache()
        assert tune.get_tiles("fused", 8, 8, 8, 8, jnp.float32, "cpu") == (8, 8, 4)

    def test_atomic_write_unique_tmp(self, tmp_path, monkeypatch):
        """Two interleaved writers must never tear the file: each write goes
        through its own temp file + os.replace, so the survivor is one
        complete JSON document."""
        from repro.kernels import autotune as tune

        path = tmp_path / "at.json"
        monkeypatch.setenv(tune.CACHE_ENV, str(path))
        a = {"a": {"tiles": [1, 2, 3], "us": 1.0}}
        b = {"b": {"tiles": [4, 5, 6], "us": 2.0}}
        tune._save_cache(a)
        tune._save_cache(b)
        on_disk = json.loads(path.read_text())
        assert on_disk == b
        # no stray temp files left behind
        leftovers = [p for p in os.listdir(tmp_path) if p.endswith(".tmp")]
        assert leftovers == []

    def test_autotune_records_sparse_winner(self, tmp_path, monkeypatch):
        from repro.kernels import autotune as tune
        from repro.kernels import ops as kops

        monkeypatch.setenv(tune.CACHE_ENV, str(tmp_path / "at.json"))
        g = SplineGrid(-1.0, 1.0, 5, 3)
        params = kl.init_kan_layer(
            jax.random.PRNGKey(0), kl.KANLayerConfig(16, 32, g)
        )
        x = jnp.asarray(
            np.random.RandomState(0).uniform(-1, 1, (8, 16)).astype(np.float32)
        )
        rep = tune.autotune(
            "sparse",
            lambda bb, bn, bk: kops.kan_sparse_gemm(
                x, params["coeff"], g, base_w=params["base_w"],
                bb=bb, bn=bn, bk=bk,
            ),
            8, 16, 32, g.n_basis, iters=1,
            candidates=[(8, 32, 8), (8, 32, 16)], nnz=g.n_nonzero,
        )
        assert tuple(rep["tiles"]) in {(8, 32, 8), (8, 32, 16)}
        assert tune.get_tiles(
            "sparse", 8, 16, 32, g.n_basis, x.dtype, jax.default_backend()
        ) == tuple(rep["tiles"])
