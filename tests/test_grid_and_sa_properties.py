"""Extra coverage: non-uniform grid refit (paper §II-B generality argument)
and SA-model invariants (hypothesis)."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from repro.core import bspline as bs
from repro.core import grid as gridlib
from repro.core import sa_model as sm


def test_nonuniform_to_uniform_refit():
    """Paper §II-B: non-uniform grids are approximated by finer uniform ones
    via least squares, 'without retraining'."""
    P = 3
    rs = np.random.RandomState(0)
    # a non-uniform interior knot spacing over [-1, 1]
    interior = np.sort(rs.uniform(-0.9, 0.9, 4))
    step_lo = interior[0] + 1.0
    step_hi = 1.0 - interior[-1]
    knots = np.concatenate([
        -1.0 - step_lo * np.arange(P, 0, -1),
        [-1.0], interior, [1.0],
        1.0 + step_hi * np.arange(1, P + 1),
    ])
    K, N = 3, 2
    M_old = len(knots) - P - 1
    coeff = jnp.asarray(rs.normal(size=(K, M_old, N)).astype(np.float32))
    new_grid, new_coeff = gridlib.nonuniform_to_uniform(knots, coeff, P, G_new=48)
    assert new_coeff.shape == (K, new_grid.n_basis, N)
    # the refit function must approximate the original spline on the domain
    xs = jnp.linspace(-0.95, 0.95, 201)
    B_new = bs.cox_de_boor_dense(xs, new_grid)
    f_new = jnp.einsum("sm,kmn->skn", B_new, new_coeff)
    assert bool(jnp.all(jnp.isfinite(f_new)))
    # reconstruct the old spline values with numpy Cox-de Boor for comparison
    b = np.where((np.asarray(xs)[:, None] >= knots[None, :-1])
                 & (np.asarray(xs)[:, None] < knots[None, 1:]), 1.0, 0.0)
    for p in range(1, P + 1):
        nb = np.zeros((len(xs), b.shape[1] - 1))
        for i in range(b.shape[1] - 1):
            d1 = knots[i + p] - knots[i]
            d2 = knots[i + p + 1] - knots[i + 1]
            left = ((np.asarray(xs) - knots[i]) / d1) * b[:, i] if d1 > 0 else 0
            right = ((knots[i + p + 1] - np.asarray(xs)) / d2) * b[:, i + 1] if d2 > 0 else 0
            nb[:, i] = left + right
        b = nb
    f_old = np.einsum("sm,kmn->skn", b[:, :M_old], np.asarray(coeff))
    err = np.abs(f_old - np.asarray(f_new)).max() / (np.abs(f_old).max() + 1e-9)
    assert err < 0.05, err


@hypothesis.given(
    R=st.sampled_from([4, 8, 16, 32]),
    C=st.sampled_from([4, 8, 16, 32]),
    BS=st.integers(1, 256),
    K=st.integers(1, 512),
    N_out=st.integers(1, 256),
    G=st.integers(2, 10),
    P=st.integers(1, 3),
)
@hypothesis.settings(max_examples=50, deadline=None)
def test_sa_model_invariants(R, C, BS, K, N_out, G, P):
    """Utilization in (0, 1]; KAN-SAs >= conventional; cycles scale with M."""
    wl = sm.GEMMWorkload("w", BS, K, N_out, G, P, kan=True)
    conv = sm.run_workload(sm.SAConfig(R, C, "scalar"), wl)
    kans = sm.run_workload(sm.SAConfig(R, C, "nm", N=P + 1, M=G + P), wl)
    assert 0 < conv.utilization <= 1.0
    assert 0 < kans.utilization <= 1.0
    # utilization dominance holds whenever the array's rows can be filled
    # (K >= R); for degenerate K < R the vector PE's idle lanes can lose —
    # the same imperfect-tiling effect the paper discusses in Fig 8.
    if K >= R:
        assert kans.utilization >= conv.utilization - 1e-9
    assert conv.cycles >= kans.cycles
    # exact cycle relation when tiling is perfect
    if (K * (G + P)) % R == 0 and K % R == 0 and N_out % C == 0:
        assert abs(conv.cycles / kans.cycles - (G + P)) < 1e-9


def test_pe_area_monotone_in_lanes():
    a = [sm.pe_area_um2(n, 8) for n in (1, 2, 4)]
    assert a[0] < a[1] < a[2]
