"""Substrate tests: optimizer, train step, checkpointing (+elastic restore),
serving engine, data determinism, sharding rules, MoE dispatch, compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import store
from repro.data import pipeline as dp
from repro.dist import compression, sharding as SH
from repro.models import lm
from repro.models.layers import Axes
from repro.optim import adamw
from repro.serve.engine import Engine, ServeConfig
from repro.train import step as train_step_lib


class TestOptimizer:
    def test_adamw_reduces_loss(self):
        cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                                weight_decay=0.0, schedule="constant")
        params = {"w": jnp.asarray([2.0, -3.0])}
        state = adamw.init_state(params)
        loss = lambda p: jnp.sum(p["w"] ** 2)
        for _ in range(120):  # Adam's per-step move is bounded by lr
            g = jax.grad(loss)(params)
            params, state, m = adamw.apply_updates(params, g, state, cfg)
        assert float(loss(params)) < 0.1

    def test_schedule_shapes(self):
        cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
        assert float(adamw.lr_at(cfg, jnp.asarray(0))) < 0.2
        assert abs(float(adamw.lr_at(cfg, jnp.asarray(10))) - 1.0) < 0.11
        assert float(adamw.lr_at(cfg, jnp.asarray(100))) <= 0.2


class TestTrainStep:
    def test_loss_decreases_kanformer(self):
        """End-to-end: the paper-technique LM trains (grad accum on)."""
        arch = configs.get_reduced("kanformer-100m")
        tstep = jax.jit(train_step_lib.make_train_step(
            arch.model, adamw.AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=60),
            compute_dtype=jnp.float32, accum_steps=2,
        ))
        params = lm.init_params(jax.random.PRNGKey(0), arch.model)
        opt = adamw.init_state(params)
        data = dp.LMDataConfig(vocab=arch.model.vocab, seq_len=32, global_batch=8)
        losses = []
        for i in range(30):
            params, opt, m = tstep(params, opt, dp.lm_batch(data, i))
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses

    def test_grad_compression_roundtrip(self):
        g = {"a": jnp.asarray(np.random.RandomState(0).normal(size=(64,)).astype(np.float32))}
        for kind in ("bf16", "int8"):
            out = compression.compress_tree(g, kind)
            rel = float(jnp.abs(out["a"] - g["a"]).max() / jnp.abs(g["a"]).max())
            assert rel < (0.02 if kind == "int8" else 0.01), (kind, rel)


class TestCheckpoint:
    def test_roundtrip_and_resume(self, tmp_path):
        tree = {"w": jnp.arange(12.0).reshape(3, 4), "s": jnp.asarray(7)}
        store.save(str(tmp_path), 10, tree)
        store.save(str(tmp_path), 20, jax.tree.map(lambda x: x + 1, tree))
        assert store.latest_step(str(tmp_path)) == 20
        restored, mf = store.restore(str(tmp_path), 20, tree)
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]) + 1)
        assert mf["step"] == 20

    def test_partial_checkpoint_ignored(self, tmp_path):
        tree = {"w": jnp.zeros((2,))}
        store.save(str(tmp_path), 5, tree)
        # simulate crash mid-write: tmp dir left behind
        os.makedirs(tmp_path / "step_00000009.tmp")
        assert store.latest_step(str(tmp_path)) == 5

    def test_async_checkpointer(self, tmp_path):
        ck = store.AsyncCheckpointer(str(tmp_path), keep=2)
        tree = {"w": jnp.zeros((8,))}
        for s in (1, 2, 3):
            ck.save_async(s, tree)
        ck.wait()
        assert store.all_steps(str(tmp_path)) == [2, 3]  # gc keeps 2

    def test_elastic_restore_changes_sharding(self, tmp_path):
        """Restore re-shards onto a different mesh (1 host device here)."""
        arch = configs.get_reduced("qwen1.5-0.5b")
        params = lm.init_params(jax.random.PRNGKey(0), arch.model)
        opt = adamw.init_state(params)
        store.save(str(tmp_path), 3, (params, opt))
        from repro.launch.elastic import restore_elastic
        from repro.launch.mesh import make_host_mesh

        p2, o2, mf = restore_elastic(
            str(tmp_path), 3, arch.model, make_host_mesh(), jnp.float32
        )
        chex_equal = jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
            params, p2,
        )
        del chex_equal
        assert mf["step"] == 3


class TestData:
    def test_deterministic_across_restarts(self):
        cfg = dp.LMDataConfig(vocab=100, seq_len=16, global_batch=4, seed=3)
        a = dp.lm_batch(cfg, 7)
        b = dp.lm_batch(cfg, 7)
        np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
        c = dp.lm_batch(cfg, 8)
        assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))

    def test_labels_are_shifted_tokens(self):
        cfg = dp.LMDataConfig(vocab=100, seq_len=16, global_batch=2)
        b = dp.lm_batch(cfg, 0)
        np.testing.assert_array_equal(
            np.asarray(b["labels"][:, :-1]), np.asarray(b["tokens"][:, 1:])
        )


class TestServeEngine:
    def test_generate_matches_stepwise_greedy(self):
        arch = configs.get_reduced("qwen2.5-3b")
        params = lm.init_params(jax.random.PRNGKey(0), arch.model)
        eng = Engine(params, arch.model, ServeConfig(max_seq=48, max_new_tokens=8))
        prompts = np.random.RandomState(0).randint(0, arch.model.vocab, (2, 6)).astype(np.int32)
        out = eng.generate(prompts)
        assert out.shape == (2, 8)
        # greedy decode must be reproducible
        out2 = eng.generate(prompts)
        np.testing.assert_array_equal(out, out2)

    def test_serve_requests_batching(self):
        arch = configs.get_reduced("qwen1.5-0.5b")
        params = lm.init_params(jax.random.PRNGKey(1), arch.model)
        eng = Engine(params, arch.model, ServeConfig(max_seq=40, max_new_tokens=4))
        rs = np.random.RandomState(1)
        reqs = [rs.randint(0, 100, rs.randint(3, 9)).astype(np.int32) for _ in range(5)]
        outs = eng.serve_requests(reqs, batch_size=3)
        assert len(outs) == 5 and all(o.shape == (4,) for o in outs)


class TestShardingRules:
    def _mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_divisibility_fallback(self):
        import jax.sharding as js

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # heads=40 with model=1 divides trivially; simulate size via fake mesh
        spec = SH.spec_for(Axes(("embed", "heads", "head_dim")), (64, 40, 16), mesh)
        assert isinstance(spec, js.PartitionSpec)

    def test_rules_on_fake_mesh(self):
        """The real divisibility logic, on shapes that don't divide."""
        # fabricate a mesh dict-alike via the actual API with 1 device but
        # pretend sizes using the internal helpers
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        spec = SH.spec_for(Axes(("vocab", "embed")), (151936, 1024), mesh)
        assert spec[0] == "model"  # vocab takes the model axis

    def test_zero_spec_adds_data(self):
        import jax.sharding as js

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        base = js.PartitionSpec(None, "model")
        z = SH.zero_spec(base, (64, 32), mesh)
        assert z[0] == "data"


class TestMoEDispatch:
    def test_capacity_drops_counted(self):
        import dataclasses

        from repro.models import moe
        from repro.models.layers import ParamCtx

        cfg = moe.MoEConfig(d_model=16, d_ff=8, n_experts=4, top_k=2,
                            capacity_factor=0.5, dispatch="scatter")
        params = moe.moe_init(ParamCtx("init", jax.random.PRNGKey(0)), cfg)
        x = jnp.asarray(np.random.RandomState(0).normal(size=(1, 64, 16)).astype(np.float32))
        _, aux = moe.moe_forward(params, cfg, x)
        assert float(aux["moe_drop_frac"]) > 0  # capacity 0.5 must drop
        cfg2 = dataclasses.replace(cfg, capacity_factor=4.0)
        _, aux2 = moe.moe_forward(params, cfg2, x)
        assert float(aux2["moe_drop_frac"]) == 0.0
