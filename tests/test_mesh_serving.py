"""Mesh-native serving equivalence battery (DESIGN.md §4 "serving on a mesh").

Runs in a SUBPROCESS with 8 fake CPU host devices (the conftest helper sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before jax
initialises) and proves, on a 2x4 (data, model) mesh:

* ``Engine.serve_continuous`` / ``serve_requests`` token outputs are
  IDENTICAL to the single-device engine across every serving config axis —
  dense / paged, prefix caching on / off, int8 KV on / off, greedy /
  sampled, shadow / step paged reads.  Tokens (argmax / categorical picks)
  are compared exactly; logits themselves may differ in the last ulp
  because partitioned contractions reorder fp32 partial sums (the
  documented tolerance — see ``serve/engine.py``).
* params and KV leaves are *actually distributed* (``.sharding``
  assertions: model axis on heads/kv_heads, data axis on slots/blocks,
  per-device shards strictly smaller than the logical array) — not
  silently replicated or gathered.
* a 1-device mesh is token-bit-identical to ``mesh=None`` (no behavior
  change from threading the ShardingCtx).
"""

import textwrap

from conftest import run_jax_subprocess

SCRIPT = textwrap.dedent(
    """
    import jax, numpy as np
    from repro import configs
    from repro.configs.common import enable_kv_quant
    from repro.models import lm
    from repro.serve.engine import Engine, ServeConfig
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh((2, 4))
    assert dict(mesh.shape) == {"data": 2, "model": 4}, mesh.shape

    kan = configs.get_reduced("kanformer-100m")
    q8 = enable_kv_quant(configs.get_reduced("qwen1.5-0.5b"))
    params = {a.model.name: lm.init_params(jax.random.PRNGKey(0), a.model)
              for a in (kan, q8)}

    rs = np.random.RandomState(0)
    shared = rs.randint(0, 512, 8).astype(np.int32)   # prefix-cache fodder
    reqs = [np.concatenate([shared,
                            rs.randint(0, 512, rs.randint(3, 10)).astype(np.int32)])
            for _ in range(5)]

    def outputs(arch, mesh_arg, serve_kw, slots=2):
        eng = Engine(params[arch.model.name], arch.model,
                     ServeConfig(max_seq=48, max_new_tokens=8, **serve_kw,
                                 mesh=mesh_arg))
        return eng.serve_continuous(list(reqs), slots=slots, chunk_steps=4)

    # the four config axes (dense/paged, prefix on/off, int8 on/off,
    # greedy/sampled) + both paged read paths
    MATRIX = [
        ("dense_greedy", kan, {}),
        ("dense_sampled", kan, {"temperature": 0.7}),
        ("paged_prefix_greedy", kan,
         {"paged": True, "block_size": 8, "prefix_caching": True}),
        ("paged_noprefix_sampled", kan,
         {"paged": True, "block_size": 8, "prefix_caching": False,
          "temperature": 0.7}),
        ("paged_step_read", kan,
         {"paged": True, "block_size": 8, "paged_read": "step"}),
        ("paged_data_sharded_pool", kan,
         {"paged": True, "block_size": 8, "pool_blocks": 14}),
        ("dense_int8", q8, {}),
        ("paged_int8", q8, {"paged": True, "block_size": 8}),
    ]
    for tag, arch, kw in MATRIX:
        ref = outputs(arch, None, kw)
        got = outputs(arch, mesh, kw)
        assert all((a == b).all() for a, b in zip(ref, got)), tag
        print("OK", tag)

    # static bucketing driver too (generate under the hood)
    eng0 = Engine(params[kan.model.name], kan.model,
                  ServeConfig(max_seq=48, max_new_tokens=8, temperature=0.5))
    engm = Engine(params[kan.model.name], kan.model,
                  ServeConfig(max_seq=48, max_new_tokens=8, temperature=0.5,
                              mesh=mesh))
    a = eng0.serve_requests(list(reqs), batch_size=4)
    b = engm.serve_requests(list(reqs), batch_size=4)
    assert all((x == y).all() for x, y in zip(a, b))
    print("OK static_sampled")

    # ---- distribution proofs: sharded, not replicated ------------------
    wq = engm.params["unit"][0]["attn"]["wq"]        # (layers, d, heads, hd)
    assert "model" in tuple(wq.sharding.spec), wq.sharding
    assert not wq.sharding.is_fully_replicated
    assert wq.addressable_shards[0].data.shape[2] == wq.shape[2] // 4

    dense = engm._make_dense_caches(4)
    dk = dense["unit"][0]["k"]                       # (layers, B, S, kv, hd)
    spec = tuple(dk.sharding.spec)
    assert spec[1] == "data" and spec[3] == "model", spec
    assert dk.addressable_shards[0].data.shape[1] == dk.shape[1] // 2
    assert dk.addressable_shards[0].data.shape[3] == dk.shape[3] // 4

    pool = engm._make_paged_caches(16, 8)            # divisible block count
    pk = pool["unit"][0]["k"]                        # (layers, nb, bs, kv, hd)
    spec = tuple(pk.sharding.spec)
    assert spec[1] == "data" and spec[3] == "model", spec
    assert pk.addressable_shards[0].data.shape[1] == pk.shape[1] // 2

    # int8 pools: values AND scales stay distributed
    engq = Engine(params[q8.model.name], q8.model,
                  ServeConfig(max_seq=48, max_new_tokens=8, mesh=mesh))
    qpool = engq._make_paged_caches(16, 8)
    qs = qpool["unit"][0]["k_scale"]                 # (layers, nb, bs, kv)
    assert tuple(qs.sharding.spec)[3] == "model", qs.sharding
    print("OK distribution")

    # ---- 1-device mesh: bit-identical to mesh=None ---------------------
    m1 = make_host_mesh((1, 1))
    for tag, arch, kw in (MATRIX[0], MATRIX[3]):
        ref = outputs(arch, None, kw)
        got = outputs(arch, m1, kw)
        assert all((a == b).all() for a, b in zip(ref, got)), tag
    print("OK mesh1x1")
    print("ALL_OK")
    """
)


def test_mesh_serving_equivalence_subprocess():
    proc = run_jax_subprocess(SCRIPT, devices=8, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "ALL_OK" in proc.stdout, proc.stdout
