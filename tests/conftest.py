"""Test-suite bootstrap.

Two services:

* :func:`run_jax_subprocess` (also a fixture, ``jax_subprocess``) — run a
  python snippet or argv in a SUBPROCESS with a clean jax environment:
  ``JAX_PLATFORMS=cpu`` always (without it jax probes the TPU runtime on
  TPU-image hosts and spends minutes in GCP-metadata retries) and, for
  ``devices > 1``, ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
  set BEFORE jax initialises — the only way to fake a multi-device host.
  Sharding/dist tests use this instead of hand-rolling env plumbing.

* a deterministic mini property-testing shim installed under the
  ``hypothesis`` module names when the real package is unavailable (it is
  declared in requirements.txt and installed in CI, but hermetic containers
  may lack it), so the property tests still *run* — each ``@given`` draws
  ``max_examples`` pseudo-random examples from a fixed seed.  The shim
  covers exactly the API surface this suite uses: ``given``, ``settings``,
  ``strategies.integers/floats/sampled_from/booleans/just``.
"""

from __future__ import annotations

import random
import subprocess
import sys
import types

import pytest


def run_jax_subprocess(
    code: str | None = None,
    argv: list[str] | None = None,
    devices: int = 1,
    timeout: int = 900,
    env_extra: dict | None = None,
) -> subprocess.CompletedProcess:
    """Run ``python -c code`` (or ``python *argv``) with the repo on
    PYTHONPATH, jax forced onto CPU, and optionally ``devices`` fake host
    devices.  Returns the CompletedProcess (caller asserts on
    returncode/stdout)."""
    assert (code is None) != (argv is None), "pass exactly one of code/argv"
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"}
    if devices > 1:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    if env_extra:
        env.update(env_extra)
    cmd = [sys.executable] + (["-c", code] if code is not None else list(argv))
    return subprocess.run(
        cmd, capture_output=True, text=True, timeout=timeout, env=env, cwd=".",
    )


@pytest.fixture
def jax_subprocess():
    """Fixture handle on :func:`run_jax_subprocess` (multi-device CPU
    subprocess runner) for tests that prefer injection over import."""
    return run_jax_subprocess


@pytest.fixture
def assert_trace_budget():
    """Assert an Engine's retrace sentinel matches a documented program
    budget: ``check(engine, {"decode_chunk": 1, ...})``.  A *program* is a
    distinct abstract signature traced for that jitted entry point
    (``repro.analysis.retrace``); budgets pin the compile counts the serving
    PRs promised (DESIGN.md invariant catalogue).  Names absent from the
    budget are unconstrained; names in the budget but never traced count 0.
    """
    def check(engine, budget: dict) -> None:
        snap = engine.compiles.snapshot()
        got = {n: snap.get(n, {}).get("programs", 0) for n in budget}
        assert got == budget, (
            f"trace budget violated: expected {budget}, got {got}; "
            f"full snapshot: {snap}"
        )
    return check

try:  # pragma: no cover - prefer the real thing
    import hypothesis  # noqa: F401
except ImportError:
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rng: random.Random):
            return self._draw(rng)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def floats(min_value, max_value, allow_nan=False, allow_infinity=False,
               width=64):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: rng.choice(seq))

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def just(value):
        return _Strategy(lambda rng: value)

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._mini_hyp_settings = {"max_examples": max_examples}
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            conf = getattr(fn, "_mini_hyp_settings", {"max_examples": 20})

            def wrapper():
                rng = random.Random(0x5EED)
                for n in range(conf["max_examples"]):
                    kwargs = {
                        k: s.example_from(rng) for k, s in strategies.items()
                    }
                    try:
                        fn(**kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example (draw {n}): {kwargs}"
                        ) from e

            # No functools.wraps: pytest must see a zero-arg signature, not
            # the strategy parameters (it would treat them as fixtures).
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    def assume(condition):
        if not condition:
            raise AssertionError("mini-hypothesis: assume() not satisfiable")

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.sampled_from = sampled_from
    st_mod.booleans = booleans
    st_mod.just = just

    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = given
    hyp_mod.settings = settings
    hyp_mod.assume = assume
    hyp_mod.strategies = st_mod
    hyp_mod.__mini_shim__ = True

    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod
