"""Test-suite bootstrap.

When the real ``hypothesis`` package is unavailable (it is declared in
requirements.txt and installed in CI, but hermetic containers may lack it),
install a deterministic mini property-testing shim under the same module
names so the property tests still *run* — each ``@given`` draws
``max_examples`` pseudo-random examples from a fixed seed.  The shim covers
exactly the API surface this suite uses: ``given``, ``settings``,
``strategies.integers/floats/sampled_from/booleans/just``.
"""

from __future__ import annotations

import random
import sys
import types

try:  # pragma: no cover - prefer the real thing
    import hypothesis  # noqa: F401
except ImportError:
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rng: random.Random):
            return self._draw(rng)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def floats(min_value, max_value, allow_nan=False, allow_infinity=False,
               width=64):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: rng.choice(seq))

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def just(value):
        return _Strategy(lambda rng: value)

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._mini_hyp_settings = {"max_examples": max_examples}
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            conf = getattr(fn, "_mini_hyp_settings", {"max_examples": 20})

            def wrapper():
                rng = random.Random(0x5EED)
                for n in range(conf["max_examples"]):
                    kwargs = {
                        k: s.example_from(rng) for k, s in strategies.items()
                    }
                    try:
                        fn(**kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example (draw {n}): {kwargs}"
                        ) from e

            # No functools.wraps: pytest must see a zero-arg signature, not
            # the strategy parameters (it would treat them as fixtures).
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    def assume(condition):
        if not condition:
            raise AssertionError("mini-hypothesis: assume() not satisfiable")

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.sampled_from = sampled_from
    st_mod.booleans = booleans
    st_mod.just = just

    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = given
    hyp_mod.settings = settings
    hyp_mod.assume = assume
    hyp_mod.strategies = st_mod
    hyp_mod.__mini_shim__ = True

    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod
