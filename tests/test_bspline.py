"""Property + unit tests for the B-spline core (paper §II-A, §III-B)."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bspline as bs
from repro.core.bspline import SplineGrid

GRIDS = [(5, 3), (3, 3), (10, 3), (2, 1), (3, 2), (4, 4), (7, 2)]


def _x(n=128, lo=-1.0, hi=1.0, seed=0):
    return jnp.asarray(
        np.random.RandomState(seed).uniform(lo, hi, (n,)).astype(np.float32)
    )


@pytest.mark.parametrize("G,P", GRIDS)
def test_partition_of_unity(G, P):
    """sum_m B_m(x) == 1 on the whole domain (incl. the endpoints)."""
    g = SplineGrid(-1.0, 1.0, G, P)
    x = jnp.concatenate([_x(), jnp.asarray([-1.0, 1.0, 0.0])])
    dense = bs.cox_de_boor_dense(x, g)
    np.testing.assert_allclose(np.asarray(dense.sum(-1)), 1.0, atol=1e-5)


@pytest.mark.parametrize("G,P", GRIDS)
def test_local_support_nm_sparsity(G, P):
    """Paper §IV-A: at most N = P+1 of M = G+P values are non-zero, and they
    are contiguous at positions k-P..k."""
    g = SplineGrid(-1.0, 1.0, G, P)
    x = _x(512)
    dense = np.asarray(bs.cox_de_boor_dense(x, g))
    k = np.asarray(bs.interval_index(x, g))
    nz = dense > 1e-9
    assert nz.sum(-1).max() <= P + 1
    for m in range(g.n_basis):
        rows = nz[:, m]
        assert np.all((m >= k[rows] - P) & (m <= k[rows])), "non-contiguous support"


@pytest.mark.parametrize("G,P", GRIDS)
def test_compact_matches_dense(G, P):
    g = SplineGrid(-1.0, 1.0, G, P)
    x = _x(256)
    vals, k = bs.compact_basis(x, g)
    dense = bs.compact_to_dense(vals, k, g)
    ref = bs.cox_de_boor_dense(x, g)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("G,P", GRIDS)
def test_lut_matches_exact(G, P):
    """Tabulated path (Fig. 5) converges to the exact values as S grows."""
    g = SplineGrid(-1.0, 1.0, G, P)
    x = _x(256)
    ref = bs.cox_de_boor_dense(x, g)
    for S, tol in [(256, 2e-2), (4096, 1.5e-3)]:
        lut = jnp.asarray(bs.build_lut(P, S))
        dense = bs.lut_basis_dense(x, g, lut)
        assert float(jnp.abs(dense - ref).max()) < tol


def test_cardinal_symmetry():
    """B_{0,P}(t) == B_{0,P}(P+1-t) — the half-table property (§III-B)."""
    for P in (1, 2, 3, 4):
        t = jnp.linspace(0.0, P + 1.0, 257)
        a = bs.cardinal_bspline(t, P)
        b = bs.cardinal_bspline((P + 1.0) - t, P)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_translation_invariance_eq4():
    """Paper Eq. 4: B_{t_k,P}(x) = B_{0,P}((x-t0)/delta - k)."""
    g = SplineGrid(-2.0, 3.0, 6, 3)
    x = _x(128, -2.0, 3.0)
    dense = np.asarray(bs.cox_de_boor_dense(x, g))
    z = np.asarray(bs.align(x, g))
    for m in range(g.n_basis):
        via_cardinal = np.asarray(bs.cardinal_bspline(jnp.asarray(z - m), 3))
        np.testing.assert_allclose(dense[:, m], via_cardinal, atol=1e-5)


@hypothesis.given(
    G=st.integers(1, 12),
    P=st.integers(1, 4),
    lo=st.floats(-10, 0, allow_nan=False),
    width=st.floats(0.5, 20, allow_nan=False),
    seed=st.integers(0, 2**16),
)
@hypothesis.settings(max_examples=30, deadline=None)
def test_property_invariants(G, P, lo, width, seed):
    """System invariants over random grids: partition of unity, N:M bound,
    compact==dense, k in range."""
    g = SplineGrid(lo, lo + width, G, P)
    x = _x(64, lo, lo + width, seed=seed % 1000)
    dense = bs.cox_de_boor_dense(x, g)
    np.testing.assert_allclose(np.asarray(dense.sum(-1)), 1.0, atol=1e-4)
    assert int((np.asarray(dense) > 1e-7).sum(-1).max()) <= P + 1
    vals, k = bs.compact_basis(x, g)
    assert int(k.min()) >= P and int(k.max()) <= G + P - 1
    np.testing.assert_allclose(
        np.asarray(bs.compact_to_dense(vals, k, g)), np.asarray(dense), atol=1e-4
    )


def test_grad_flows_through_dense():
    g = SplineGrid(-1.0, 1.0, 5, 3)
    c = jnp.asarray(np.random.RandomState(1).normal(size=(g.n_basis,)).astype(np.float32))
    f = lambda x: (bs.cox_de_boor_dense(x, g) * c).sum()
    got = jax.grad(f)(jnp.asarray(0.3))
    eps = 1e-3
    fd = (f(jnp.asarray(0.3 + eps)) - f(jnp.asarray(0.3 - eps))) / (2 * eps)
    np.testing.assert_allclose(float(got), float(fd), rtol=1e-2)


def test_out_of_domain_clamps():
    g = SplineGrid(-1.0, 1.0, 5, 3)
    x = jnp.asarray([-5.0, 5.0])
    vals, k = bs.compact_basis(x, g)
    assert int(k[0]) == g.P and int(k[1]) == g.n_basis - 1
    assert bool(jnp.all(jnp.isfinite(vals)))
