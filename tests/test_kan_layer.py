"""KAN layer path-equivalence, quantisation, SA model and grid tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import grid as gridlib
from repro.core import kan_layer as kl
from repro.core import quantization as q
from repro.core import sa_model as sm
from repro.core.bspline import SplineGrid, build_lut


def _layer(G=5, P=3, K=24, N=16, seed=0):
    g = SplineGrid(-1.0, 1.0, G, P)
    cfg = kl.KANLayerConfig(K, N, g)
    params = kl.init_kan_layer(jax.random.PRNGKey(seed), cfg)
    x = jnp.asarray(np.random.RandomState(seed).uniform(-1, 1, (40, K)).astype(np.float32))
    return g, cfg, params, x


class TestPathEquivalence:
    def test_compact_equals_dense(self):
        g, _, params, x = _layer()
        a = kl.kan_layer_apply(params, x, g, "dense")
        b = kl.kan_layer_apply(params, x, g, "compact")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)

    def test_fused_equals_dense(self):
        g, _, params, x = _layer()
        a = kl.kan_layer_apply(params, x, g, "dense")
        b = kl.kan_layer_apply(params, x, g, "fused")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)

    def test_lut_close_to_dense(self):
        g, _, params, x = _layer()
        a = kl.kan_layer_apply(params, x, g, "dense")
        b = kl.kan_layer_apply(params, x, g, "lut", lut=jnp.asarray(build_lut(3, 4096)))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)

    @pytest.mark.parametrize("G,P", [(5, 3), (10, 3), (3, 2)])
    def test_batched_leading_dims(self, G, P):
        g = SplineGrid(-1.0, 1.0, G, P)
        cfg = kl.KANLayerConfig(8, 6, g)
        params = kl.init_kan_layer(jax.random.PRNGKey(0), cfg)
        x = jnp.asarray(np.random.RandomState(0).uniform(-1, 1, (3, 5, 8)).astype(np.float32))
        y = kl.kan_layer_apply(params, x, g, "dense")
        assert y.shape == (3, 5, 6)
        y2 = kl.kan_layer_apply(params, x.reshape(15, 8), g, "dense").reshape(3, 5, 6)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-5)


class TestTraining:
    def test_kan_net_trains_on_regression(self):
        """A tiny KAN must fit a smooth target (sanity of grads + init)."""
        cfg = kl.KANNetConfig(layers=(2, 8, 1), G=5, P=3)
        params = kl.init_kan_net(jax.random.PRNGKey(0), cfg)
        rs = np.random.RandomState(0)
        X = jnp.asarray(rs.uniform(-1, 1, (256, 2)).astype(np.float32))
        Y = (jnp.sin(3 * X[:, :1]) * X[:, 1:] ** 2)

        def loss(p):
            pred = kl.kan_net_apply(p, X, cfg)
            return jnp.mean((pred - Y) ** 2)

        l0 = float(loss(params))
        lr = 0.05
        g_fn = jax.jit(jax.grad(loss))
        for _ in range(60):
            grads = g_fn(params)
            params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        l1 = float(loss(params))
        assert l1 < 0.3 * l0, (l0, l1)


class TestQuantization:
    def test_int8_forward_close(self):
        g, _, params, x = _layer()
        ref = kl.kan_layer_apply(params, x, g, "dense")
        qlayer = q.quantize_kan_layer(params, g)
        got = q.quantized_kan_forward(qlayer, x)
        err = float(jnp.abs(got - ref).max() / (jnp.abs(ref).max() + 1e-9))
        assert err < 0.15, err  # 8-bit activations; model-level accuracy is
        # validated in benchmarks/quant_accuracy.py (<1% drop, paper §V)

    def test_int_address_matches_float(self):
        """Eq. 5 integer address must agree with the float Align/Compare."""
        from repro.core import bspline as bs

        g = SplineGrid(-1.0, 1.0, 5, 3)
        qg = q.QuantizedGrid.make(g)
        x = jnp.asarray(np.random.RandomState(0).uniform(-1, 1, (4096,)).astype(np.float32))
        addr_i, k_i = q.int_address(qg, qg.x_quant.quantize(x))
        k_f = bs.interval_index(x, g)
        match = float(jnp.mean((k_i == k_f).astype(jnp.float32)))
        # 8-bit activations put ~(0.5 quant-step / interval-width) of inputs on
        # the wrong side of an interval boundary (~2% for G+2P=11 intervals on
        # 255 steps). Spline continuity makes those evaluations correct anyway
        # (B_m is continuous across knots); mismatched k must differ by 1.
        assert match > 0.95, match
        assert int(jnp.abs(k_i - k_f).max()) <= 1

    def test_lut_u8_scale_fits(self):
        for P in (1, 2, 3, 4):
            tab = q.build_lut_u8(P)
            assert tab.dtype == np.uint8
            assert tab.max() <= 255 and tab.min() >= 0


class TestSAModel:
    def test_table_i_normalized_energy(self):
        for (n, m), e in sm.TABLE_I_NORM_ENERGY.items():
            assert abs(sm.normalized_energy(n, m) - e) < 0.01

    def test_mnist_utilizations_match_paper(self):
        wl = sm._mlp_chain("MNIST", [784, 64, 10], 10, 3, 64)
        conv = sm.run_suite(sm.SAConfig(32, 32, "scalar"), wl)
        kans = sm.run_suite(sm.SAConfig(16, 16, "nm", N=4, M=13), wl)
        assert abs(conv.utilization - 0.30) < 0.01          # paper: ~30%
        assert abs(kans.utilization - 0.9925) < 0.0005      # paper: 99.25%

    def test_calibration_areas(self):
        assert abs(sm.SAConfig(32, 32, "scalar").area_mm2() - 0.50) < 1e-6
        assert abs(sm.SAConfig(16, 16, "nm", N=4, M=8).area_mm2() - 0.47) < 1e-6

    def test_arkane_72x(self):
        assert sm.arkane_equiv_units(3) == 72

    def test_cycle_reduction_about_2x(self):
        """Paper §V headline: ~50% cycle reduction at iso-area."""
        apps = sm.paper_workloads(64, fixed_gp=(5, 3))
        ratios = []
        for ws in apps.values():
            c = sm.run_suite(sm.SAConfig(32, 32, "scalar"), ws)
            k = sm.run_suite(sm.SAConfig(16, 16, "nm", N=4, M=8), ws)
            ratios.append(c.cycles / k.cycles)
        avg = float(np.mean(ratios))
        assert 1.5 < avg < 2.6, avg


class TestGridRefinement:
    def test_refit_preserves_function(self):
        g_old = SplineGrid(-1.0, 1.0, 4, 3)
        coeff = jnp.asarray(
            np.random.RandomState(0).normal(size=(6, g_old.n_basis, 5)).astype(np.float32)
        )
        g_new = gridlib.refine_grid(g_old, 3)
        coeff_new = gridlib.refit_coefficients(coeff, g_old, g_new)
        from repro.core import bspline as bs

        xs = jnp.linspace(-0.99, 0.99, 333)
        f_old = jnp.einsum("sm,kmn->skn", bs.cox_de_boor_dense(xs, g_old), coeff)
        f_new = jnp.einsum("sm,kmn->skn", bs.cox_de_boor_dense(xs, g_new), coeff_new)
        err = float(jnp.abs(f_old - f_new).max() / jnp.abs(f_old).max())
        assert err < 1e-3, err


class TestConvKAN:
    def test_conv_kan_shapes(self):
        g = SplineGrid(-1.0, 1.0, 3, 3)
        cfg = kl.KANLayerConfig(3 * 3 * 4, 8, g)
        params = kl.init_kan_layer(jax.random.PRNGKey(0), cfg)
        x = jnp.asarray(np.random.RandomState(0).uniform(-1, 1, (2, 8, 8, 4)).astype(np.float32))
        y = kl.conv_kan_apply(params, x, g, 3, 3, 1, 1)
        assert y.shape == (2, 8, 8, 8)
        assert bool(jnp.all(jnp.isfinite(y)))
