"""Pipeline parallelism (GPipe over the pod axis): pipelined loss must equal
the plain loss. Runs in a subprocess with 8 fake devices (pod=2)."""

import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import configs
    from repro.models import lm
    from repro.train import pipeline as PP

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    arch = configs.get_reduced("qwen1.5-0.5b")
    model = arch.model   # 2 repeats -> 2 stages x 1
    params = lm.init_params(jax.random.PRNGKey(0), model)
    rs = np.random.RandomState(0)
    B, T = 8, 16
    batch = {
        "tokens": jnp.asarray(rs.randint(0, model.vocab, (B, T)), jnp.int32),
        "labels": jnp.asarray(rs.randint(0, model.vocab, (B, T)), jnp.int32),
    }
    ref_loss, _ = lm.lm_loss(params, model, batch, jnp.float32)

    staged = PP.stage_params(params, 2)
    staged["unit"] = [jax.device_put(
        p, jax.tree.map(lambda _: NamedSharding(mesh, P("pod")), p))
        for p in staged["unit"]]
    loss_fn = PP.make_pp_loss(model, n_stages=2, microbatches=4, mesh=mesh,
                              compute_dtype=jnp.float32)
    with mesh:
        pp_loss = jax.jit(loss_fn)(staged, batch)
        # gradients flow through the pipeline (ppermute + scan autodiff)
        g = jax.jit(jax.grad(lambda p, b: loss_fn(p, b)))(staged, batch)
    print("ref", float(ref_loss), "pp", float(pp_loss))
    assert abs(float(ref_loss) - float(pp_loss)) < 2e-3, (ref_loss, pp_loss)
    leaves = jax.tree.leaves(g)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves)
    gn = sum(float(jnp.sum(l.astype(jnp.float32)**2)) for l in leaves) ** 0.5
    assert gn > 0
    print("PP_OK grad_norm", gn)
    """
)


def test_pipeline_matches_plain_loss():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=900,
        # JAX_PLATFORMS=cpu: the script fakes host devices; without it jax
        # may probe a TPU runtime (slow metadata retries on TPU-image hosts)
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"}, cwd=".",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "PP_OK" in proc.stdout, proc.stdout
