"""Per-architecture smoke tests (brief requirement): instantiate a REDUCED
config of the same family, run one forward/train step and one decode step on
CPU, assert output shapes + finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm

ARCHS = configs.list_configs()


def _batch(arch, model, B=2, T=16):
    rs = np.random.RandomState(0)
    inputs = {}
    if model.input_kind == "tokens":
        inputs["tokens"] = jnp.asarray(rs.randint(0, model.vocab, (B, T)), jnp.int32)
        inputs["labels"] = jnp.asarray(rs.randint(0, model.vocab, (B, T)), jnp.int32)
    elif model.input_kind == "embeddings":
        inputs["embeddings"] = jnp.asarray(
            rs.normal(size=(B, T, model.d_model)).astype(np.float32)
        )
        inputs["labels"] = jnp.asarray(rs.randint(0, model.vocab, (B, T)), jnp.int32)
    else:  # mixed
        tt = T - model.n_prefix
        inputs["prefix_embeddings"] = jnp.asarray(
            rs.normal(size=(B, model.n_prefix, model.d_model)).astype(np.float32)
        )
        inputs["tokens"] = jnp.asarray(rs.randint(0, model.vocab, (B, tt)), jnp.int32)
        inputs["labels"] = jnp.asarray(rs.randint(0, model.vocab, (B, tt)), jnp.int32)
    return inputs


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad(arch):
    cfg = configs.get_reduced(arch)
    model = cfg.model
    params = lm.init_params(jax.random.PRNGKey(0), model)
    B, T = 2, 16
    inputs = _batch(arch, model, B, T)
    logits, aux = lm.forward(params, model, inputs, compute_dtype=jnp.float32)
    T_total = T if model.input_kind != "mixed" else T
    assert logits.shape == (B, T_total, model.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"

    # one train step (loss + grads finite)
    loss, metrics = lm.lm_loss(params, model, inputs, compute_dtype=jnp.float32)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: lm.lm_loss(p, model, inputs, jnp.float32)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), f"{arch}: bad grads"
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in leaves) ** 0.5
    assert gnorm > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = configs.get_reduced(arch)
    model = cfg.model
    params = lm.init_params(jax.random.PRNGKey(0), model)
    B, S = 2, 32
    caches = lm.init_caches(model, B, S, dtype=jnp.float32)
    pos = jnp.zeros((B,), jnp.int32)
    if model.input_kind == "embeddings":
        tok = jnp.zeros((B, 1, model.d_model), jnp.float32)
    else:
        tok = jnp.zeros((B, 1), jnp.int32)
    logits, caches = lm.decode_step(params, model, tok, caches, pos, jnp.float32)
    assert logits.shape == (B, model.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite decode"
    # second step at pos 1 reuses updated caches
    logits2, _ = lm.decode_step(params, model, tok, caches, pos + 1, jnp.float32)
    assert bool(jnp.all(jnp.isfinite(logits2)))


def test_decode_matches_prefill_qwen():
    """Teacher-forced decode must reproduce the prefill logits (KV-cache
    correctness), checked on the smallest dense arch."""
    cfg = configs.get_reduced("qwen1.5-0.5b")
    model = cfg.model
    params = lm.init_params(jax.random.PRNGKey(1), model)
    B, T = 1, 8
    rs = np.random.RandomState(1)
    toks = jnp.asarray(rs.randint(0, model.vocab, (B, T)), jnp.int32)
    logits_full, _ = lm.forward(params, model, {"tokens": toks}, jnp.float32)

    caches = lm.init_caches(model, B, T, dtype=jnp.float32)
    outs = []
    for t in range(T):
        pos = jnp.full((B,), t, jnp.int32)
        lg, caches = lm.decode_step(
            params, model, toks[:, t : t + 1], caches, pos, jnp.float32
        )
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(logits_full), rtol=2e-4, atol=2e-4
    )


def test_decode_matches_prefill_ssm():
    """Same equivalence for the recurrent families (zamba2 SSD + xlstm)."""
    for arch in ("zamba2-1.2b", "xlstm-1.3b"):
        cfg = configs.get_reduced(arch)
        model = cfg.model
        params = lm.init_params(jax.random.PRNGKey(2), model)
        B, T = 1, 8
        rs = np.random.RandomState(2)
        toks = jnp.asarray(rs.randint(0, model.vocab, (B, T)), jnp.int32)
        logits_full, _ = lm.forward(params, model, {"tokens": toks}, jnp.float32)
        caches = lm.init_caches(model, B, T, dtype=jnp.float32)
        outs = []
        for t in range(T):
            pos = jnp.full((B,), t, jnp.int32)
            lg, caches = lm.decode_step(
                params, model, toks[:, t : t + 1], caches, pos, jnp.float32
            )
            outs.append(lg)
        dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(dec), np.asarray(logits_full), rtol=3e-3, atol=3e-3,
            err_msg=arch,
        )
