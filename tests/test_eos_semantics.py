"""EOS semantics (PR satellite): early-exit latches the row — the EOS
token is emitted, every later position is ``pad_id``, deterministically —
``eos_id = -1`` reproduces the never-stop behavior bit-for-bit, scan and
loop decode impls agree on truncated outputs, and continuous batching
actually *frees* a latched slot (one slot can serve many EOS-ing requests).

EOS ids are picked from tokens the greedy model really emits, so the latch
provably fires (no vocabulary guessing).
"""

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serve.engine import Engine, ServeConfig

MAX_NEW = 6
PAD = 0


@pytest.fixture(scope="module")
def setup():
    arch = configs.get_reduced("qwen1.5-0.5b")
    params = lm.init_params(jax.random.PRNGKey(0), arch.model)
    cfg = dict(max_seq=48, max_new_tokens=MAX_NEW, pad_id=PAD)
    scan = Engine(params, arch.model, ServeConfig(**cfg))
    loop = Engine(params, arch.model, ServeConfig(**cfg, decode_impl="loop"))
    rs = np.random.RandomState(3)
    reqs = [rs.randint(0, 100, L).astype(np.int32) for L in (5, 8, 11, 6)]
    # never-stop references, one per request
    refs = [scan.generate(r[None].astype(np.int32), seed=0,
                          request_ids=np.asarray([i]))[0]
            for i, r in enumerate(reqs)]
    return scan, loop, reqs, refs


def _latched(ref: np.ndarray, eos: int) -> np.ndarray:
    """Host-side oracle: tokens up to and including the first EOS, then
    pad_id to the fixed length."""
    out = np.full_like(ref, PAD)
    hits = np.nonzero(ref == eos)[0]
    k = int(hits[0]) if hits.size else len(ref) - 1
    out[: k + 1] = ref[: k + 1]
    return out


def test_eos_latches_row_and_pads_tail(setup):
    scan, _, reqs, refs = setup
    for i, (r, ref) in enumerate(zip(reqs, refs)):
        for k in (1, 3):
            eos = int(ref[k])
            got = scan.generate(r[None].astype(np.int32), seed=0,
                                request_ids=np.asarray([i]), eos_id=eos)[0]
            np.testing.assert_array_equal(_latched(ref, eos), got)
            # post-EOS tail is exactly pad_id — deterministic masking
            first = int(np.nonzero(ref == eos)[0][0])
            assert (got[first + 1:] == PAD).all()


def test_first_token_eos(setup):
    scan, _, reqs, refs = setup
    eos = int(refs[0][0])
    got = scan.generate(reqs[0][None].astype(np.int32), seed=0,
                        request_ids=np.asarray([0]), eos_id=eos)[0]
    expect = np.full(MAX_NEW, PAD, np.int32)
    expect[0] = eos
    np.testing.assert_array_equal(expect, got)


def test_eos_minus1_preserves_never_stop(setup):
    scan, _, reqs, refs = setup
    got = scan.generate(reqs[1][None].astype(np.int32), seed=0,
                        request_ids=np.asarray([1]), eos_id=-1)[0]
    np.testing.assert_array_equal(refs[1], got)


def test_scan_and_loop_agree_on_truncated_outputs(setup):
    scan, loop, reqs, refs = setup
    for i in (0, 2):
        eos = int(refs[i][2])
        a = scan.generate(reqs[i][None].astype(np.int32), seed=0,
                          request_ids=np.asarray([i]), eos_id=eos)
        b = loop.generate(reqs[i][None].astype(np.int32), seed=0,
                          request_ids=np.asarray([i]), eos_id=eos)
        np.testing.assert_array_equal(a, b)
    # and without EOS
    a = scan.generate(reqs[3][None].astype(np.int32), seed=0,
                      request_ids=np.asarray([3]))
    b = loop.generate(reqs[3][None].astype(np.int32), seed=0,
                      request_ids=np.asarray([3]))
    np.testing.assert_array_equal(a, b)


def test_eos_in_ragged_batch_matches_solo(setup):
    """EOS latching is per-row: rows latch at different steps inside one
    mixed-length batch without perturbing each other."""
    scan, _, reqs, refs = setup
    eos = int(refs[2][1])
    T = max(len(r) for r in reqs)
    padded = np.stack([np.pad(r, (0, T - len(r))) for r in reqs]).astype(np.int32)
    lens = np.asarray([len(r) for r in reqs], np.int32)
    batch = scan.generate(padded, seed=0, lengths=lens,
                          request_ids=np.arange(len(reqs)), eos_id=eos)
    for i, r in enumerate(reqs):
        one = scan.generate(r[None].astype(np.int32), seed=0,
                            request_ids=np.asarray([i]), eos_id=eos)[0]
        np.testing.assert_array_equal(one, batch[i])


def test_continuous_eos_frees_slots(setup):
    """EOS early-exit actually recycles the slot: ONE slot serves a queue
    of requests that all latch early, outputs stay bit-identical to solo,
    and the scheduler retires everything cleanly."""
    scan, _, reqs, refs = setup
    eos = int(refs[2][1])
    old = scan.cfg.eos_id
    scan.cfg.eos_id = eos
    try:
        outs = scan.serve_continuous(reqs, slots=1, chunk_steps=2, seed=0)
        for i, r in enumerate(reqs):
            one = scan.generate(r[None].astype(np.int32), seed=0,
                                request_ids=np.asarray([i]), eos_id=eos)[0]
            np.testing.assert_array_equal(one, outs[i])
        stats = scan.last_serve_stats
        assert stats["n_served"] == len(reqs)
        # the latch saved work: request 2 EOSes by its second token, so the
        # total useful tokens are strictly below the full-budget drain
        assert stats["useful_tokens"] < len(reqs) * MAX_NEW
    finally:
        scan.cfg.eos_id = old
