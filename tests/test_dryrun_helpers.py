"""Unit tests for dry-run helpers that don't need 512 devices."""

import importlib
import sys
import types

import pytest


def _load_collective_bytes():
    """Import dryrun.collective_bytes without triggering the 512-device
    XLA_FLAGS (the module sets os.environ at import; jax is already
    initialised in this process, so the flag is inert here)."""
    from repro.launch.dryrun import collective_bytes

    return collective_bytes


HLO = """
ENTRY %main {
  %ar = f32[1024,512]{1,0} all-reduce(%x), replica_groups=[2,4]<=[8]
  %ag = bf16[64,2048]{1,0} all-gather-start(%y), dimensions={1}
  %agd = bf16[64,2048]{1,0} all-gather-done(%ag)
  %rs = f32[256]{0} reduce-scatter(%z), dimensions={0}
  %a2a = (s8[16,16]{1,0}, s8[16,16]{1,0}) all-to-all(%p, %q)
  %cp = f32[8,8]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %dot = f32[128,128]{1,0} dot(%a, %b)
}
"""


def test_collective_bytes_parser():
    collective_bytes = _load_collective_bytes()
    out = collective_bytes(HLO)
    assert out["all-reduce"] == 1024 * 512 * 4
    assert out["all-gather"] == 64 * 2048 * 2      # -start only, no double count
    assert out["reduce-scatter"] == 256 * 4
    assert out["all-to-all"] == 2 * 16 * 16 * 1    # tuple: both elements
    assert out["collective-permute"] == 8 * 8 * 4
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_accum_steps_policy():
    from repro.launch.dryrun import _accum_steps

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    # 256 global batch, seq 4096 -> 16 per dev -> microbatch 2 -> accum 8
    assert _accum_steps(256, 4096, FakeMesh()) == 8

    class FakeMeshMulti:
        shape = {"pod": 2, "data": 16, "model": 16}

    assert _accum_steps(256, 4096, FakeMeshMulti()) == 4
