"""Per-kernel allclose tests vs the pure-jnp oracles (shape/dtype sweeps).

All kernels run in interpret mode on CPU (the TPU target compiles the same
code through Mosaic).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantization as q
from repro.core.bspline import SplineGrid, build_lut
from repro.kernels import ops, ref

SHAPES = [
    # (G, P, BS, K, N)
    (5, 3, 64, 16, 32),
    (5, 3, 100, 37, 50),     # ragged: exercises padding
    (10, 3, 64, 20, 10),     # MNIST-KAN-like basis
    (3, 2, 33, 5, 7),
    (2, 1, 17, 3, 4),
    (3, 3, 1, 22, 60),       # BS=1 decode-like
]


@pytest.mark.parametrize("G,P", [(5, 3), (10, 3), (3, 2), (2, 1)])
@pytest.mark.parametrize("n", [64, 300, 1025])
def test_bspline_lut_kernel(G, P, n):
    g = SplineGrid(-1.0, 1.0, G, P)
    x = jnp.asarray(np.random.RandomState(n).uniform(-1, 1, (n,)).astype(np.float32))
    lut = jnp.asarray(build_lut(P, 256))
    vals, k = ops.bspline_lut(x, lut, g, block=128, interpret=True)
    rvals, rk = ref.ref_bspline_compact(x, g, lut)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(rk))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), atol=1e-6)


@pytest.mark.parametrize("G,P,BS,K,N", SHAPES)
def test_kan_fused_gemm_kernel(G, P, BS, K, N):
    g = SplineGrid(-1.0, 1.0, G, P)
    rs = np.random.RandomState(BS + K)
    x = jnp.asarray(rs.uniform(-1, 1, (BS, K)).astype(np.float32))
    coeff = jnp.asarray(rs.normal(size=(K, g.n_basis, N)).astype(np.float32))
    y = ops.kan_fused_gemm(x, coeff, g, bb=32, bn=32, bk=8, interpret=True)
    yr = ref.ref_kan_gemm(x, coeff, g)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kan_fused_gemm_dtypes(dtype):
    g = SplineGrid(-1.0, 1.0, 5, 3)
    rs = np.random.RandomState(7)
    x = jnp.asarray(rs.uniform(-1, 1, (64, 16)).astype(np.float32)).astype(dtype)
    coeff = jnp.asarray(rs.normal(size=(16, g.n_basis, 32)).astype(np.float32)).astype(dtype)
    y = ops.kan_fused_gemm(x, coeff, g, bb=32, bn=32, bk=8, interpret=True)
    yr = ref.ref_kan_gemm(x.astype(jnp.float32), coeff.astype(jnp.float32), g)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(y, dtype=np.float32), np.asarray(yr), rtol=tol, atol=tol * 10
    )


@pytest.mark.parametrize("G,P,BS,K,N", SHAPES)
def test_kan_int8_gemm_kernel_bit_exact(G, P, BS, K, N):
    """The integer kernel must match the integer oracle *exactly*."""
    g = SplineGrid(-1.0, 1.0, G, P)
    rs = np.random.RandomState(BS * 7 + K)
    x = jnp.asarray(rs.uniform(-1, 1, (BS, K)).astype(np.float32))
    qg = q.QuantizedGrid.make(g)
    xq = qg.x_quant.quantize(x)
    lut8 = jnp.asarray(q.build_lut_u8(P, 256))
    cq = jnp.asarray(rs.randint(-127, 128, (K, g.n_basis, N)).astype(np.int8))
    y = ops.kan_int8_gemm(xq, lut8, cq, g, bb=32, bn=32, bk=8, interpret=True)
    yr = ref.ref_kan_gemm_int8(xq, cq, lut8, g)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


def test_fused_gemm_block_size_invariance():
    """Result must not depend on the tiling (hardware-shape independence)."""
    g = SplineGrid(-1.0, 1.0, 5, 3)
    rs = np.random.RandomState(3)
    x = jnp.asarray(rs.uniform(-1, 1, (70, 30)).astype(np.float32))
    coeff = jnp.asarray(rs.normal(size=(30, g.n_basis, 40)).astype(np.float32))
    outs = [
        ops.kan_fused_gemm(x, coeff, g, bb=bb, bn=bn, bk=bk, interpret=True)
        for (bb, bn, bk) in [(16, 16, 4), (32, 64, 8), (128, 128, 16)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=1e-4)
