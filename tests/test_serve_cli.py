"""CLI smoke tests for the serving launcher (PR satellite).

The previous ``--reduced`` flag was ``action="store_true", default=True`` —
syntactically present but impossible to turn off.  It is now ``--full``
(default: reduced); both selection paths are covered here, plus subprocess
smoke runs of the static and continuous engines at reduced shapes.
"""

import subprocess

from conftest import run_jax_subprocess
from repro.launch.serve import build_parser, pick_config

ARCH = "qwen1.5-0.5b"


def test_full_flag_defaults_off_and_toggles():
    args = build_parser().parse_args(["--arch", ARCH])
    assert args.full is False
    args = build_parser().parse_args(["--arch", ARCH, "--full"])
    assert args.full is True


def test_pick_config_selects_both_paths():
    reduced = pick_config(ARCH, full=False)
    full = pick_config(ARCH, full=True)
    assert reduced.model.d_model < full.model.d_model
    assert reduced.model.name == full.model.name


def _run_cli(*extra: str, devices: int = 1) -> subprocess.CompletedProcess:
    return run_jax_subprocess(
        argv=["-m", "repro.launch.serve", "--arch", ARCH,
              "--requests", "3", "--batch", "2", "--prompt-len", "8",
              "--max-new", "4", *extra],
        devices=devices,
    )


def test_cli_static_engine_smoke():
    proc = _run_cli("--engine", "static")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "[serve:static]" in proc.stdout, proc.stdout


def test_cli_continuous_engine_smoke():
    proc = _run_cli("--engine", "continuous", "--chunk-steps", "2")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "[serve:continuous]" in proc.stdout, proc.stdout
    assert "slot_utilization=" in proc.stdout, proc.stdout


def test_cli_paged_engine_smoke():
    proc = _run_cli("--engine", "continuous", "--chunk-steps", "2",
                    "--paged", "--block-size", "4")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "[serve:paged]" in proc.stdout, proc.stdout
    assert "blocks_watermark=" in proc.stdout, proc.stdout


def test_cli_mesh_continuous_smoke():
    """--mesh 2x2 on a forced-4-device host: the continuous engine runs on
    a real (data, model) mesh end to end (sharded params + KV)."""
    proc = _run_cli("--engine", "continuous", "--chunk-steps", "2",
                    "--mesh", "2x2", devices=4)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "mesh={'data': 2, 'model': 2}" in proc.stdout, proc.stdout
    assert "[serve:continuous]" in proc.stdout, proc.stdout


def test_cli_mesh_1x1_static_smoke():
    """--mesh 1x1 works on a plain single-device host (the degenerate mesh
    is the bit-identical fallback path)."""
    proc = _run_cli("--mesh", "1x1")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "[serve:static]" in proc.stdout, proc.stdout


def test_cli_mesh_invalid_shape_errors():
    proc = _run_cli("--mesh", "3x3")   # 9 devices on a 1-device host
    assert proc.returncode == 2, (proc.stdout, proc.stderr)
    assert "host mesh 3x3" in proc.stderr, proc.stderr


def test_cli_paged_requires_continuous():
    args = build_parser().parse_args(
        ["--arch", ARCH, "--paged", "--block-size", "4"])
    assert args.paged and args.engine == "static"
    proc = _run_cli("--paged")
    assert proc.returncode == 2
    assert "--paged requires --engine continuous" in proc.stderr
