"""CLI smoke tests for the serving launcher (PR satellite).

The previous ``--reduced`` flag was ``action="store_true", default=True`` —
syntactically present but impossible to turn off.  It is now ``--full``
(default: reduced); both selection paths are covered here, plus subprocess
smoke runs of the static and continuous engines at reduced shapes.
"""

import subprocess
import sys

from repro.launch.serve import build_parser, pick_config

ARCH = "qwen1.5-0.5b"


def test_full_flag_defaults_off_and_toggles():
    args = build_parser().parse_args(["--arch", ARCH])
    assert args.full is False
    args = build_parser().parse_args(["--arch", ARCH, "--full"])
    assert args.full is True


def test_pick_config_selects_both_paths():
    reduced = pick_config(ARCH, full=False)
    full = pick_config(ARCH, full=True)
    assert reduced.model.d_model < full.model.d_model
    assert reduced.model.name == full.model.name


def _run_cli(*extra: str) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", ARCH,
         "--requests", "3", "--batch", "2", "--prompt-len", "8",
         "--max-new", "4", *extra],
        capture_output=True, text=True, timeout=900,
        # JAX_PLATFORMS=cpu: without it jax may probe a TPU runtime (slow
        # metadata retries on TPU-image hosts)
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"}, cwd=".",
    )


def test_cli_static_engine_smoke():
    proc = _run_cli("--engine", "static")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "[serve:static]" in proc.stdout, proc.stdout


def test_cli_continuous_engine_smoke():
    proc = _run_cli("--engine", "continuous", "--chunk-steps", "2")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "[serve:continuous]" in proc.stdout, proc.stdout
    assert "slot_utilization=" in proc.stdout, proc.stdout


def test_cli_paged_engine_smoke():
    proc = _run_cli("--engine", "continuous", "--chunk-steps", "2",
                    "--paged", "--block-size", "4")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "[serve:paged]" in proc.stdout, proc.stdout
    assert "blocks_watermark=" in proc.stdout, proc.stdout


def test_cli_paged_requires_continuous():
    args = build_parser().parse_args(
        ["--arch", ARCH, "--paged", "--block-size", "4"])
    assert args.paged and args.engine == "static"
    proc = _run_cli("--paged")
    assert proc.returncode == 2
    assert "--paged requires --engine continuous" in proc.stderr
