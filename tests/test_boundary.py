"""Boundary-value suite (PR acceptance criteria):

* every evaluation path — dense oracle, ``compact_basis``, LUT, fused
  kernel, int8 kernel, sparse kernel, sparse int8 kernel — agrees at
  ``x_min``, ``x_max``, interior knot points, and out-of-domain inputs
  (shared convention: Eq. 5 saturation);
* the basis at exactly ``x = x_max`` is non-zero and identical across
  paths (the half-open-interval all-zero regression);
* clamped (repeated-end-knot) non-uniform refits are no longer corrupted
  at the right edge;
* ``refit_coefficients`` survives bf16 coefficients (fp32-promoted solve).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bspline as bs
from repro.core import grid as gr
from repro.core import kan_layer as kl
from repro.core import quantization as q
from repro.core.bspline import SplineGrid

GRIDS = [(5, 3), (3, 2), (10, 3), (2, 1), (4, 4)]


def _boundary_points(g: SplineGrid) -> np.ndarray:
    """x_min, x_max, every interior knot, and out-of-domain on both sides."""
    interior = g.knots()[g.P : g.n_basis + 1]      # x_min .. x_max inclusive
    span = g.x_max - g.x_min
    return np.concatenate(
        [interior, [g.x_min - 0.5 * span, g.x_max + 0.5 * span,
                    g.x_min - 5 * span, g.x_max + 5 * span]]
    ).astype(np.float32)


@pytest.mark.parametrize("G,P", GRIDS)
def test_basis_nonzero_and_unit_at_xmax(G, P):
    """The endpoint regression: the dense oracle at x == x_max is a valid
    partition-of-unity row (was structurally dependent on extension
    intervals; all-zero for clamped knots)."""
    g = SplineGrid(-1.0, 1.0, G, P)
    row = np.asarray(bs.cox_de_boor_dense(jnp.asarray([g.x_max], jnp.float32), g))[0]
    assert row.max() > 0.1, row
    np.testing.assert_allclose(row.sum(), 1.0, atol=1e-5)


@pytest.mark.parametrize("G,P", GRIDS)
def test_all_basis_paths_agree_at_boundaries(G, P):
    """dense == compact == LUT (dense-scattered) at endpoints, knots and
    out-of-domain points — one saturation convention everywhere."""
    g = SplineGrid(-1.0, 1.0, G, P)
    x = jnp.asarray(_boundary_points(g))
    dense = np.asarray(bs.cox_de_boor_dense(x, g))
    np.testing.assert_allclose(dense.sum(-1), 1.0, atol=1e-5)
    vals, k = bs.compact_basis(x, g)
    np.testing.assert_allclose(
        np.asarray(bs.compact_to_dense(vals, k, g)), dense, atol=1e-5
    )
    lut = jnp.asarray(bs.build_lut(P, 4096))
    assert float(jnp.abs(bs.lut_basis_dense(x, g, lut) - dense).max()) < 2e-3


@pytest.mark.parametrize("G,P", GRIDS)
def test_kernel_paths_agree_at_boundaries(G, P):
    """Layer outputs: dense oracle vs fused and sparse Pallas kernels on the
    boundary points (same clamp semantics inside the kernels)."""
    g = SplineGrid(-1.0, 1.0, G, P)
    K, N = 7, 9
    params = kl.init_kan_layer(jax.random.PRNGKey(0), kl.KANLayerConfig(K, N, g))
    pts = _boundary_points(g)
    x = jnp.asarray(np.stack([np.roll(pts, j) for j in range(K)], axis=1))
    ref = kl.kan_layer_apply(params, x, g, "dense")
    for method in ("compact", "fused", "sparse"):
        got = kl.kan_layer_apply(params, x, g, method)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4,
            err_msg=f"method={method} G={G} P={P}",
        )


@pytest.mark.parametrize("G,P", [(5, 3), (3, 2)])
def test_int8_paths_agree_at_boundaries(G, P):
    """Integer paths at the boundary points: dense-band and sparse int8
    kernels are bit-identical, and both track the float oracle within
    quantisation error."""
    from repro.kernels import ops as kops

    g = SplineGrid(-1.0, 1.0, G, P)
    K, N = 6, 8
    rs = np.random.RandomState(0)
    pts = _boundary_points(g)
    x = jnp.asarray(np.stack([np.roll(pts, j) for j in range(K)], axis=1))
    qg = q.QuantizedGrid.make(g)
    x_q = qg.x_quant.quantize(x)
    lut_u8 = jnp.asarray(q.build_lut_u8(P, 256))
    cq = jnp.asarray(rs.randint(-127, 128, (K, g.n_basis, N)).astype(np.int8))
    y_band = kops.kan_int8_gemm(x_q, lut_u8, cq, g, bb=8, bn=8, bk=4)
    y_sparse = kops.kan_sparse_int8_gemm(x_q, lut_u8, cq, g, bb=8, bn=8, bk=4)
    np.testing.assert_array_equal(np.asarray(y_band), np.asarray(y_sparse))
    # both track the float spline term within quantisation error (the
    # oracle saturates out-of-domain inputs the same way the address
    # arithmetic does)
    ref = jnp.einsum(
        "bkm,kmn->bn", bs.cox_de_boor_dense(x, g), cq.astype(jnp.float32)
    )
    got = y_band.astype(jnp.float32) / qg.lut_scale
    scale = float(jnp.abs(ref).max()) + 1e-9
    assert float(jnp.abs(got - ref).max()) / scale < 5e-2


def test_clamped_nonuniform_refit_right_edge():
    """Clamped (repeated end-knot) vectors: the basis row at x_max used to
    be all-zero, corrupting the lstsq targets. The refit must now
    reproduce the spline up to AND INCLUDING the right edge."""
    P, G_old = 3, 5
    kn = np.concatenate(
        [np.full(P, -1.0), np.linspace(-1, 1, G_old + 1), np.full(P, 1.0)]
    )
    rs = np.random.RandomState(0)
    coeff = jnp.asarray(rs.randn(2, G_old + P, 3).astype(np.float32))
    new_grid, new_coeff = gr.nonuniform_to_uniform(kn, coeff, P, 20, n_samples=256)

    # reference: exact clamped-basis evaluation at probe points (scipy-free
    # Cox-de Boor with the closed right edge)
    def clamped_basis(xs):
        b = np.where(
            (xs[:, None] >= kn[None, :-1]) & (xs[:, None] < kn[None, 1:]), 1.0, 0.0
        )
        dom = np.where((kn[:-1] < kn[1:]) & (kn[1:] <= 1.0 + 1e-12))[0]
        last = int(dom.max())
        edge = xs >= kn[last + 1]
        b[edge] = 0.0
        b[edge, last] = 1.0
        for p in range(1, P + 1):
            nb = np.zeros((len(xs), b.shape[1] - 1))
            for i in range(b.shape[1] - 1):
                d1, d2 = kn[i + p] - kn[i], kn[i + p + 1] - kn[i + 1]
                left = ((xs - kn[i]) / d1) * b[:, i] if d1 > 0 else 0.0
                right = ((kn[i + p + 1] - xs) / d2) * b[:, i + 1] if d2 > 0 else 0.0
                nb[:, i] = left + right
            b = nb
        return b[:, : G_old + P]

    probe = np.linspace(-1.0, 1.0, 41)
    f_ref = np.einsum("sm,kmn->skn", clamped_basis(probe), np.asarray(coeff))
    B_new = np.asarray(bs.cox_de_boor_dense(jnp.asarray(probe, jnp.float32), new_grid))
    f_new = np.einsum("sm,kmn->skn", B_new, np.asarray(new_coeff))
    scale = np.abs(f_ref).max() + 1e-9
    err = np.abs(f_new - f_ref).max() / scale
    assert err < 5e-2, err
    # the edge specifically (the previously-corrupted sample)
    edge_err = np.abs(f_new[-1] - f_ref[-1]).max() / scale
    assert edge_err < 5e-2, edge_err


def test_refit_bf16_coefficients():
    """The lstsq solve is fp32-promoted: a bf16 refit must land within bf16
    resolution of the fp32 refit (previously garbage-or-unsupported)."""
    g = SplineGrid(-1.0, 1.0, 5, 3)
    g2 = gr.refine_grid(g, 2)
    rs = np.random.RandomState(0)
    c32 = jnp.asarray(rs.randn(3, g.n_basis, 4).astype(np.float32))
    ref = gr.refit_coefficients(c32, g, g2, n_samples=128)
    c16 = c32.astype(jnp.bfloat16)
    got = gr.refit_coefficients(c16, g, g2, n_samples=128)
    assert got.dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(got.astype(jnp.float32))))
    scale = float(jnp.abs(ref).max()) + 1e-9
    err = float(jnp.abs(got.astype(jnp.float32) - ref).max()) / scale
    assert err < 5e-2, err
