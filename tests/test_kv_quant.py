"""int8 KV-cache decode (serving-memory feature) vs bf16-cache reference,
plus the paged carry-over: int8 pool blocks must read bit-equal to the
int8 dense cache (KANtize's low-bit treatment survives the paged layout)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.common import enable_kv_quant
from repro.models import lm
from repro.serve.engine import Engine, ServeConfig


def test_kv_quant_decode_close_to_fp():
    base = configs.get_reduced("qwen1.5-0.5b")
    quant = enable_kv_quant(base)
    params = lm.init_params(jax.random.PRNGKey(0), base.model)
    B, T = 2, 12
    rs = np.random.RandomState(0)
    toks = jnp.asarray(rs.randint(0, base.model.vocab, (B, T)), jnp.int32)

    def teacher_force(model):
        caches = lm.init_caches(model, B, T, dtype=jnp.float32)
        outs = []
        for t in range(T):
            lg, caches = lm.decode_step(
                params, model, toks[:, t : t + 1], caches,
                jnp.asarray(t, jnp.int32), jnp.float32,
            )
            outs.append(lg)
        return jnp.stack(outs, 1)

    fp = teacher_force(base.model)
    q8 = teacher_force(quant.model)
    # int8 cache: logits close; top-1 prediction nearly always identical
    rel = float(jnp.abs(fp - q8).max() / (jnp.abs(fp).max() + 1e-9))
    agree = float((jnp.argmax(fp, -1) == jnp.argmax(q8, -1)).mean())
    assert rel < 0.1, rel
    assert agree > 0.9, agree


def test_paged_quant_decode_bit_equal_dense_quant():
    """Quantized paged reads == quantized dense reads, bit for bit: the
    pool stores the same int8 values + fp32 scales the dense cache stores
    (identical per-(token, kv-head) quantization), the gather is pure data
    movement, and the chunked dequant flash-decode runs unchanged on the
    gathered view."""
    quant = enable_kv_quant(configs.get_reduced("qwen1.5-0.5b"))
    model = quant.model
    params = lm.init_params(jax.random.PRNGKey(0), model)
    B, max_seq, bs = 2, 24, 4
    nlog = max_seq // bs
    rs = np.random.RandomState(7)
    T = 6
    toks = rs.randint(0, model.vocab, (B, T)).astype(np.int32)
    logits_d, caches_d = lm.prefill(
        params, model, {"tokens": jnp.asarray(toks)}, max_seq, jnp.float32
    )
    n_blocks = 2 * B * nlog + 1
    pools = lm.init_paged_caches(model, n_blocks, bs, jnp.float32)
    perm = rs.permutation(np.arange(1, n_blocks))[: B * nlog]
    tables = jnp.asarray(perm.reshape(B, nlog).astype(np.int32))
    last_p, pools = lm.prefill_into_pages(
        params, model, jnp.asarray(toks), jnp.full((B,), T, jnp.int32),
        tables, pools, 0, jnp.float32,
    )
    # prefill attention sees RAW K/V on both paths; stored blocks are int8
    np.testing.assert_array_equal(
        np.asarray(logits_d[:, T - 1]), np.asarray(last_p)
    )
    assert pools["unit"][0]["k"].dtype == jnp.int8
    tok = jnp.argmax(last_p, -1).astype(jnp.int32)[:, None]
    pos = jnp.full((B,), T, jnp.int32)
    for _ in range(4):
        lg_d, caches_d = lm.decode_step(
            params, model, tok, caches_d, pos, jnp.float32
        )
        lg_p, pools = lm.decode_step(
            params, model, tok, pools, pos, jnp.float32, table=tables
        )
        np.testing.assert_array_equal(np.asarray(lg_d), np.asarray(lg_p))
        tok = jnp.argmax(lg_p, -1).astype(jnp.int32)[:, None]
        pos = pos + 1


def test_paged_quant_serving_bit_equal_dense_and_solo():
    """End-to-end: int8-cache paged serve_continuous == int8 dense
    serve_continuous == int8 solo generate (prefix reuse auto-disables
    under quant — reused blocks could only supply dequantized prefill
    values, and bit-identity wins)."""
    quant = enable_kv_quant(configs.get_reduced("qwen1.5-0.5b"))
    model = quant.model
    params = lm.init_params(jax.random.PRNGKey(0), model)
    rs = np.random.RandomState(2)
    reqs = [rs.randint(0, model.vocab, L).astype(np.int32)
            for L in (5, 9, 9, 12)]
    dense = Engine(params, model, ServeConfig(max_seq=32, max_new_tokens=5))
    paged = Engine(params, model,
                   ServeConfig(max_seq=32, max_new_tokens=5, paged=True,
                               block_size=4, pool_blocks=20))
    out_d = dense.serve_continuous(reqs, slots=2, chunk_steps=2, seed=0)
    out_p = paged.serve_continuous(reqs, slots=2, chunk_steps=2, seed=0)
    for i, r in enumerate(reqs):
        ref = dense.generate(r[None].astype(np.int32), seed=0,
                             request_ids=np.asarray([i]))[0]
        np.testing.assert_array_equal(ref, out_d[i])
        np.testing.assert_array_equal(ref, out_p[i])
    assert paged.last_serve_stats["paged"].get("prefix_caching") is False


def test_ring_buffer_matches_full_cache():
    """Windowed ring cache must reproduce full-cache attention exactly when
    the window covers the whole history."""
    import dataclasses

    base = configs.get_reduced("gemma3-12b")  # has local window=8 layers
    model = base.model
    params = lm.init_params(jax.random.PRNGKey(1), model)
    B, T = 1, 8  # history <= window: ring == full
    rs = np.random.RandomState(1)
    toks = jnp.asarray(rs.randint(0, model.vocab, (B, T)), jnp.int32)
    logits_full, _ = lm.forward(params, model, {"tokens": toks}, jnp.float32)
    caches = lm.init_caches(model, B, T, dtype=jnp.float32)
    outs = []
    for t in range(T):
        lg, caches = lm.decode_step(
            params, model, toks[:, t : t + 1], caches,
            jnp.asarray(t, jnp.int32), jnp.float32,
        )
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(logits_full), rtol=3e-4, atol=3e-4
    )
