"""int8 KV-cache decode (serving-memory feature) vs bf16-cache reference."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.common import enable_kv_quant
from repro.models import lm


def test_kv_quant_decode_close_to_fp():
    base = configs.get_reduced("qwen1.5-0.5b")
    quant = enable_kv_quant(base)
    params = lm.init_params(jax.random.PRNGKey(0), base.model)
    B, T = 2, 12
    rs = np.random.RandomState(0)
    toks = jnp.asarray(rs.randint(0, base.model.vocab, (B, T)), jnp.int32)

    def teacher_force(model):
        caches = lm.init_caches(model, B, T, dtype=jnp.float32)
        outs = []
        for t in range(T):
            lg, caches = lm.decode_step(
                params, model, toks[:, t : t + 1], caches,
                jnp.asarray(t, jnp.int32), jnp.float32,
            )
            outs.append(lg)
        return jnp.stack(outs, 1)

    fp = teacher_force(base.model)
    q8 = teacher_force(quant.model)
    # int8 cache: logits close; top-1 prediction nearly always identical
    rel = float(jnp.abs(fp - q8).max() / (jnp.abs(fp).max() + 1e-9))
    agree = float((jnp.argmax(fp, -1) == jnp.argmax(q8, -1)).mean())
    assert rel < 0.1, rel
    assert agree > 0.9, agree


def test_ring_buffer_matches_full_cache():
    """Windowed ring cache must reproduce full-cache attention exactly when
    the window covers the whole history."""
    import dataclasses

    base = configs.get_reduced("gemma3-12b")  # has local window=8 layers
    model = base.model
    params = lm.init_params(jax.random.PRNGKey(1), model)
    B, T = 1, 8  # history <= window: ring == full
    rs = np.random.RandomState(1)
    toks = jnp.asarray(rs.randint(0, model.vocab, (B, T)), jnp.int32)
    logits_full, _ = lm.forward(params, model, {"tokens": toks}, jnp.float32)
    caches = lm.init_caches(model, B, T, dtype=jnp.float32)
    outs = []
    for t in range(T):
        lg, caches = lm.decode_step(
            params, model, toks[:, t : t + 1], caches,
            jnp.asarray(t, jnp.int32), jnp.float32,
        )
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(logits_full), rtol=3e-4, atol=3e-4
    )
