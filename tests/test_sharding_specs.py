"""dist/sharding.py edge cases the mesh serving path now hits.

These are pure spec-derivation tests: ``spec_for``/``zero_spec``/
``batch_spec`` only read ``mesh.shape``, so a stub mesh object is enough —
no fake-device subprocess needed (the end-to-end distribution proofs live
in ``tests/test_mesh_serving.py``).
"""

import jax
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    ShardingCtx,
    batch_spec,
    spec_for,
    zero_spec,
)
from repro.models.layers import Axes


class StubMesh:
    """Only what the spec rules read: an axis-name -> size mapping."""

    def __init__(self, **shape):
        self.shape = shape


MESH = StubMesh(data=2, model=4)
POD_MESH = StubMesh(pod=2, data=2, model=2)


def _axes(*names):
    return Axes(tuple(names))


# ---------------------------------------------------------------------------
# spec_for: model-axis divisibility fallback
# ---------------------------------------------------------------------------


def test_spec_for_full_replication_when_nothing_divides_model():
    """No dimension divisible by the model axis -> the model axis is simply
    not placed (full replication on the tensor-parallel axis); the data
    axes may still find a home."""
    spec = spec_for(_axes("embed", "ffn"), (5, 7), MESH)
    assert spec == P(None, None)
    # with a divisible batch the data axis still lands
    spec = spec_for(_axes("batch", "ffn"), (6, 7), MESH)
    assert spec == P("data", None)


def test_spec_for_model_priority_falls_through_on_divisibility():
    """ffn outranks heads, but when ffn doesn't divide the model axis the
    next priority (heads) takes it — per-tensor fallback, not global."""
    spec = spec_for(_axes("ffn", "heads"), (6, 8), MESH)
    assert spec == P(None, "model")


def test_spec_for_cache_batch1_falls_through_to_seq_cache():
    """B=1 long-context decode: the batch can't occupy the data axes, so
    the KV cache's seq_cache dimension takes them instead."""
    names = _axes("batch", "seq_cache", "kv_heads", "head_dim")
    spec = spec_for(names, (1, 64, 4, 16), MESH)
    assert spec == P(None, "data", "model", None)
    # and when the batch CAN take data, seq_cache stays unsharded
    spec = spec_for(names, (8, 64, 4, 16), MESH)
    assert spec == P("data", None, "model", None)


def test_spec_for_paged_pool_blocks_take_data():
    """Paged pools carry no batch/seq_cache: the kv_blocks axis absorbs the
    data axes (each DP shard holds a slice of the physical pool) while
    kv_heads still takes model."""
    names = _axes("kv_blocks", None, "kv_heads", "head_dim")
    spec = spec_for(names, (16, 8, 4, 16), MESH)
    assert spec == P("data", None, "model", None)
    # odd pool (the engine's default slots*n_logical+1 sizing): replicate
    spec = spec_for(names, (17, 8, 4, 16), MESH)
    assert spec == P(None, None, "model", None)
    # pod+data both land when the block count divides their product
    spec = spec_for(names, (16, 8, 4, 16), POD_MESH)
    assert spec == P(("pod", "data"), None, "model", None)


# ---------------------------------------------------------------------------
# zero_spec
# ---------------------------------------------------------------------------


def test_zero_spec_on_fully_sharded_spec_is_identity():
    """Every dimension already carries a mesh axis -> ZeRO has nowhere to
    put the data axes; the spec must come back unchanged (not error, not
    double-place an axis)."""
    base = P("data", "model")
    assert zero_spec(base, (8, 8), MESH) == base


def test_zero_spec_skips_used_data_axes():
    """A spec already using 'data' must not get it a second time."""
    base = P("data", None)
    assert zero_spec(base, (8, 8), MESH) == base


def test_zero_spec_adds_data_to_first_divisible_replicated_dim():
    base = P(None, "model")
    assert zero_spec(base, (7, 8), MESH) == P(None, "model")   # 7 % 2 != 0
    assert zero_spec(base, (8, 8), MESH) == P("data", "model")


# ---------------------------------------------------------------------------
# batch_spec
# ---------------------------------------------------------------------------


def test_batch_spec_batch1_replicates():
    """B=1 decode: nothing divides, the row arrays replicate (the cache's
    seq_cache dim is where the data axes go instead — see above)."""
    assert batch_spec(MESH, 1) == P(None)
    assert batch_spec(MESH, 8) == P("data")
    assert batch_spec(POD_MESH, 4) == P(("pod", "data"))
    # batch 2 on a pod mesh: the full (pod, data)=4 doesn't divide, the
    # largest single axis that does takes it
    assert batch_spec(POD_MESH, 2) == P("data")


# ---------------------------------------------------------------------------
# ShardingCtx (real 1-device mesh: the degenerate everything-replicates ctx)
# ---------------------------------------------------------------------------


def test_make_host_mesh_default_and_shapes():
    """make_host_mesh: default keeps the historical (1, n) all-model shape;
    an explicit (data, model) shape is validated against the host's device
    count (the old version force-shaped (1, n) and made host data
    parallelism impossible)."""
    import pytest

    from repro.launch.mesh import make_host_mesh, parse_mesh_shape

    n = len(jax.devices())
    mesh = make_host_mesh()
    assert dict(mesh.shape) == {"data": 1, "model": n}
    mesh = make_host_mesh((1, 1))
    assert dict(mesh.shape) == {"data": 1, "model": 1}
    with pytest.raises(ValueError, match="too few"):
        make_host_mesh((n + 1, n + 1))
    with pytest.raises(ValueError, match="positive"):
        make_host_mesh((0, 1))
    assert parse_mesh_shape("2x4") == (2, 4)
    assert parse_mesh_shape("1X1") == (1, 1)
    with pytest.raises(ValueError):
        parse_mesh_shape("2x")
    with pytest.raises(ValueError):
        parse_mesh_shape("8")


def test_sharding_ctx_single_device_degrades_to_replication():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = ShardingCtx(mesh)
    assert ctx.n_devices == 1
    # size-1 mesh axes divide everything, so specs still NAME them — but
    # the resulting sharding is functionally full replication
    assert ctx.named(("batch", "seq_cache", "kv_heads", "head_dim"),
                     (4, 32, 4, 16)).is_fully_replicated
    assert ctx.rows(4).is_fully_replicated
    assert ctx.replicated().is_fully_replicated
    # constrain is a no-op passthrough shape-wise
    import jax.numpy as jnp

    x = jnp.ones((4, 8))
    y = ctx.constrain(x, ("batch", "embed"))
    assert y.shape == x.shape
