"""Paged KV-cache subsystem: pool/prefix-cache units, gather kernel,
copy-on-write, and paged-vs-dense bit-identity at the model level.

The serving-level equivalence battery (paged ``serve_continuous`` vs solo
``generate`` under random schedules, preemption, and shared prefixes) lives
in ``tests/test_continuous_serving.py``; this module drives the layers
underneath it directly:

* ``serve/kv_pool.py`` — free list, refcounts, ownership, CoW, watermark,
  balanced-after-drain invariants (host-only, no jax);
* ``serve/prefix_cache.py`` — chained block hashing, hit capping, LRU
  eviction with pool cooperation, stale-entry removal;
* ``kernels/paged_gather.py`` — the Pallas block-table gather
  (interpret mode) bit-equal to the ``jnp.take`` fallback;
* ``models/lm.py`` paged paths — ``prefill_into_pages`` / paged
  ``decode_step`` bit-identical to the dense contiguous cache, and
  ``copy_paged_block`` as the CoW data mover.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels.paged_gather import gather_blocks
from repro.models import lm
from repro.serve.engine import Engine, ServeConfig
from repro.serve.kv_pool import BlockPool, blocks_for, worst_case_blocks
from repro.serve.prefix_cache import PrefixCache, block_keys


# ---------------------------------------------------------------------------
# BlockPool (host-only)
# ---------------------------------------------------------------------------


def test_block_pool_alloc_release_cycle_and_watermark():
    p = BlockPool(6, block_size=4)           # 5 usable, block 0 sentinel
    a = p.alloc(rid=0, n=3)
    assert len(a) == 3 and 0 not in a
    assert p.in_use() == 3 and p.free_count() == 2
    b = p.alloc(rid=1, n=2)
    assert not set(a) & set(b)
    assert p.watermark == 5
    with pytest.raises(MemoryError):
        p.alloc(rid=2, n=1)
    assert p.release_request(0) == a          # all freed (sole refs)
    assert p.in_use() == 2 and p.watermark == 5
    p.release_request(1)
    p.check_balanced(n_live_requests=0)


def test_block_pool_sharing_and_refcounts():
    p = BlockPool(8, block_size=2)
    a = p.alloc(rid=0, n=2)
    p.share(rid=1, blocks=a)                  # prefix hit: rc -> 2
    assert all(p.refcount(x) == 2 for x in a)
    assert p.release_request(0) == []         # request 1 still holds them
    assert all(p.refcount(x) == 1 for x in a)
    assert sorted(p.release_request(1)) == sorted(a)
    p.check_balanced(0)


def test_block_pool_cache_refs_and_cache_only():
    p = BlockPool(8, block_size=2)
    (blk,) = p.alloc(rid=0, n=1)
    p.cache_ref(blk)
    assert p.refcount(blk) == 2 and not p.cache_only(blk)
    p.release_request(0)
    assert p.cache_only(blk)                  # cache is now the sole holder
    assert p.cache_unref(blk)                 # ... and dropping it frees
    p.check_balanced(0)


def test_block_pool_copy_on_write():
    p = BlockPool(8, block_size=2)
    a = p.alloc(rid=0, n=2)
    assert p.copy_on_write(rid=0, logical=0) is None      # exclusive: no-op
    p.share(rid=1, blocks=a)
    res = p.copy_on_write(rid=1, logical=1)
    assert res is not None
    src, dst = res
    assert src == a[1] and dst not in a
    assert p.owned_blocks(1) == [a[0], dst]
    assert p.refcount(src) == 1 and p.refcount(dst) == 1
    assert p.n_cow == 1
    p.release_request(0), p.release_request(1)
    p.check_balanced(0)


def test_block_pool_detects_leak():
    p = BlockPool(4, block_size=2)
    p.alloc(rid=0, n=1)
    with pytest.raises(AssertionError):
        p.check_balanced(n_live_requests=0)   # rid 0 never released


def test_block_count_helpers():
    assert blocks_for(0, 4) == 0 and blocks_for(1, 4) == 1
    assert blocks_for(4, 4) == 1 and blocks_for(5, 4) == 2
    # prompt 10 + ceil(7/4)*4=8 decode positions -> 18 -> 5 blocks of 4
    assert worst_case_blocks(10, 8, 4, 4, max_seq=48) == 5
    # clamped by max_seq: writes past it are sentinel-redirected
    assert worst_case_blocks(10, 8, 4, 4, max_seq=12) == 3


# ---------------------------------------------------------------------------
# PrefixCache (host-only)
# ---------------------------------------------------------------------------


def test_prefix_chained_keys_position_dependence():
    t = np.arange(8, dtype=np.int32)
    keys = block_keys(t, 4)
    assert len(keys) == 2
    # same token block at a different chain position hashes differently
    t2 = np.concatenate([t[4:], t[:4]])
    assert block_keys(t2, 4)[0] != keys[1]
    # partial trailing block is never keyed
    assert len(block_keys(t[:7], 4)) == 1


def test_prefix_match_caps_last_full_block():
    """The last prompt token is always recomputed: a fully cached prompt
    still returns at most (len-1)//bs blocks, so sampling logits exist and
    decode writes stay out of shared blocks (no serving-path CoW)."""
    c = PrefixCache(4)
    t = np.arange(8, dtype=np.int32)
    keys = block_keys(t, 4)
    c.insert(keys[0], 5), c.insert(keys[1], 6)
    n_hit, blocks, _ = c.match(t)             # 8 tokens: cap = (8-1)//4 = 1
    assert n_hit == 1 and blocks == [5]
    n_hit, blocks, _ = c.match(np.arange(9, dtype=np.int32))  # cap = 2
    assert n_hit == 2 and blocks == [5, 6]


def test_prefix_stats_count_once_per_bound_admission():
    """match() records nothing (deferred admissions re-probe every loop
    iteration); record_admission counts one probe outcome, and only blocks
    actually probed count — the chain stops at the first miss and capped
    keys are never consulted."""
    c = PrefixCache(4)
    t = np.arange(9, dtype=np.int32)          # cap = 2 full blocks
    c.match(t), c.match(t)                    # retries: no stats
    assert c.lookups == 0 and c.hit_blocks == 0 and c.miss_blocks == 0
    c.record_admission(n_hit=0, n_tokens=9)   # cold probe: one miss
    c.record_admission(n_hit=2, n_tokens=9)   # full hit: no miss
    assert (c.lookups, c.hit_blocks, c.miss_blocks) == (2, 2, 1)
    assert c.stats()["prefix_block_hit_rate"] == 2 / 3
    c.record_admission(n_hit=0, n_tokens=4)   # cap 0: nothing probed
    assert c.miss_blocks == 1


def test_prefix_eviction_lru_with_pool():
    pool = BlockPool(8, block_size=4)
    c = PrefixCache(4)
    t = np.arange(12, dtype=np.int32)
    keys = block_keys(t, 4)
    blks = pool.alloc(rid=0, n=3)
    for k, b in zip(keys, blks):
        assert c.insert(k, b)
        pool.cache_ref(b)
    pool.release_request(0)
    # touch keys[0] so keys[1] becomes LRU
    c.match(t[:5])
    freed = c.evict_lru(pool)
    assert freed == blks[1] and len(c) == 2
    # a live request's block is skipped by eviction
    pool.share(rid=9, blocks=[blks[0]])
    assert c.evict_lru(pool) == blks[2]
    assert c.evict_lru(pool) is None          # blks[0] still request-held
    # stale-hit safety: evicted entries are gone from the map
    n_hit, blocks, _ = c.match(t)
    assert n_hit == 1 and blocks == [blks[0]]
    pool.release_request(9)


# ---------------------------------------------------------------------------
# gather kernel + device-side paged primitives
# ---------------------------------------------------------------------------


def test_gather_blocks_pallas_matches_take():
    rs = np.random.RandomState(0)
    for shape in [(9, 4, 3, 5), (9, 4, 3)]:   # KV pools and scale pools
        pool = jnp.asarray(rs.randn(*shape).astype(np.float32))
        tbl = jnp.asarray(rs.randint(0, 9, (3, 5)), jnp.int32)
        ref = gather_blocks(pool, tbl, method="take")
        pal = gather_blocks(pool, tbl, method="interpret")
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))
        assert ref.shape == (3, 5 * 4) + shape[2:]
    # int8 pools gather bit-exactly too
    pool8 = jnp.asarray(rs.randint(-127, 128, (9, 4, 3, 5)), jnp.int8)
    tbl = jnp.asarray(rs.randint(0, 9, (2, 4)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(gather_blocks(pool8, tbl, method="take")),
        np.asarray(gather_blocks(pool8, tbl, method="interpret")),
    )


def _arch():
    return configs.get_reduced("qwen1.5-0.5b")


_PARAMS = None


def _params(model):
    global _PARAMS
    if _PARAMS is None:
        _PARAMS = lm.init_params(jax.random.PRNGKey(0), model)
    return _PARAMS


def test_copy_paged_block_moves_every_leaf():
    model = _arch().model
    caches = lm.init_paged_caches(model, n_blocks=5, block_size=4,
                                  dtype=jnp.float32)
    # scribble into block 2 of every pool leaf
    caches = jax.tree.map(
        lambda a: a.at[(slice(None), 2) if a.ndim == 5 else (2,)].set(1.25),
        caches,
    )
    out = lm.copy_paged_block(caches, src=2, dst=4)
    for leaf in jax.tree.leaves(out):
        blk_ax = 1 if leaf.ndim == 5 else 0   # unit pools: leading layers
        got = np.asarray(jnp.take(leaf, 4, axis=blk_ax))
        np.testing.assert_array_equal(got, np.full_like(got, 1.25))
        # source block intact
        src = np.asarray(jnp.take(leaf, 2, axis=blk_ax))
        np.testing.assert_array_equal(src, np.full_like(src, 1.25))


def test_paged_decode_bit_equal_dense():
    """Scattered random block tables + paged decode == dense contiguous
    cache, bit for bit, across several steps (the tentpole contract at the
    model level)."""
    model = _arch().model
    params = _params(model)
    B, max_seq, bs = 2, 32, 4
    nlog = max_seq // bs
    rs = np.random.RandomState(3)
    T = 7
    toks = rs.randint(0, model.vocab, (B, T)).astype(np.int32)
    logits_d, caches_d = lm.prefill(
        params, model, {"tokens": jnp.asarray(toks)}, max_seq, jnp.float32
    )
    n_blocks = 2 * B * nlog + 1
    pools = lm.init_paged_caches(model, n_blocks, bs, jnp.float32)
    perm = rs.permutation(np.arange(1, n_blocks))[: B * nlog]
    tables = jnp.asarray(perm.reshape(B, nlog).astype(np.int32))
    lengths = jnp.full((B,), T, jnp.int32)
    last_p, pools = lm.prefill_into_pages(
        params, model, jnp.asarray(toks), lengths, tables, pools, 0,
        jnp.float32,
    )
    np.testing.assert_array_equal(
        np.asarray(logits_d[:, T - 1]), np.asarray(last_p)
    )
    tok = jnp.argmax(last_p, -1).astype(jnp.int32)[:, None]
    pos = jnp.full((B,), T, jnp.int32)
    for _ in range(5):
        lg_d, caches_d = lm.decode_step(
            params, model, tok, caches_d, pos, jnp.float32
        )
        lg_p, pools = lm.decode_step(
            params, model, tok, pools, pos, jnp.float32, table=tables
        )
        np.testing.assert_array_equal(np.asarray(lg_d), np.asarray(lg_p))
        tok = jnp.argmax(lg_p, -1).astype(jnp.int32)[:, None]
        pos = pos + 1


def test_prefix_hit_suffix_prefill_bit_equal():
    """prefill_into_pages with start > 0 (reusing another request's prefix
    blocks) returns the same last-token logits as a dense full prefill."""
    model = _arch().model
    params = _params(model)
    max_seq, bs = 32, 4
    nlog = max_seq // bs
    rs = np.random.RandomState(5)
    T = 10
    toks = rs.randint(0, model.vocab, (1, T)).astype(np.int32)
    n_blocks = 3 * nlog + 1
    pools = lm.init_paged_caches(model, n_blocks, bs, jnp.float32)
    tabA = jnp.asarray(np.arange(1, nlog + 1, dtype=np.int32))[None]
    _, pools = lm.prefill_into_pages(
        params, model, jnp.asarray(toks), jnp.asarray([T], jnp.int32),
        tabA, pools, 0, jnp.float32,
    )
    # request B shares the first 2 full blocks (8 tokens), new suffix
    toksB = toks.copy()
    toksB[:, 8:] = rs.randint(0, model.vocab, (1, T - 8))
    dense_logits, _ = lm.prefill(
        params, model, {"tokens": jnp.asarray(toksB)}, max_seq, jnp.float32
    )
    tabB = np.arange(nlog + 1, 2 * nlog + 1, dtype=np.int32)
    tabB[:2] = [1, 2]                          # reuse A's prefix blocks
    lastB, pools = lm.prefill_into_pages(
        params, model, jnp.asarray(toksB[:, 8:]), jnp.asarray([T], jnp.int32),
        jnp.asarray(tabB)[None], pools, 8, jnp.float32,
    )
    np.testing.assert_array_equal(
        np.asarray(dense_logits[:, T - 1]), np.asarray(lastB)
    )


def test_paged_rejects_unsupported_blocks():
    arch = configs.get_reduced("gemma3-12b")   # windowed local layers
    with pytest.raises(NotImplementedError):
        lm.init_paged_caches(arch.model, 8, 4, jnp.float32)


# ---------------------------------------------------------------------------
# admission validation (satellite: ValueError instead of deep assert)
# ---------------------------------------------------------------------------


def test_generate_validation_names_request_and_lengths():
    model = _arch().model
    eng = Engine(_params(model), model, ServeConfig(max_seq=16, max_new_tokens=8))
    big = np.arange(12, dtype=np.int32)[None]
    with pytest.raises(ValueError, match=r"request 7: prompt_len 12 \+ max_new 8"):
        eng.generate(big, request_ids=np.asarray([7]))
    with pytest.raises(ValueError, match="max_new must be >= 1"):
        eng.generate(big[:, :4], max_new=0)


def test_serve_continuous_validation_names_request():
    model = _arch().model
    eng = Engine(_params(model), model, ServeConfig(max_seq=16, max_new_tokens=4))
    ok = np.arange(4, dtype=np.int32)
    bad = np.arange(14, dtype=np.int32)
    with pytest.raises(ValueError, match="request 1: prompt_len 14"):
        eng.serve_continuous([ok, bad], slots=1, chunk_steps=2)
    with pytest.raises(ValueError, match="request 0: max_new"):
        eng.serve_continuous([ok], slots=1, chunk_steps=2, max_new=[0])


def test_paged_pool_too_small_is_a_clear_error():
    model = _arch().model
    eng = Engine(
        _params(model), model,
        ServeConfig(max_seq=32, max_new_tokens=8, paged=True, block_size=4,
                    pool_blocks=3),
    )
    with pytest.raises(ValueError, match="worst-case footprint"):
        eng.serve_continuous([np.arange(10, dtype=np.int32)], slots=1,
                             chunk_steps=4)
    eng2 = Engine(
        _params(model), model,
        ServeConfig(max_seq=30, max_new_tokens=4, paged=True, block_size=4),
    )
    with pytest.raises(ValueError, match="must divide max_seq"):
        eng2.serve_continuous([np.arange(4, dtype=np.int32)], slots=1,
                              chunk_steps=2)
    eng3 = Engine(
        _params(model), model,
        ServeConfig(max_seq=32, max_new_tokens=4, paged=True, block_size=4,
                    pool_blocks=1),
    )
    with pytest.raises(ValueError, match="pool_blocks must be >= 2"):
        eng3.serve_continuous([np.arange(4, dtype=np.int32)], slots=1,
                              chunk_steps=2)
