"""Continuous-batching equivalence battery (PR acceptance criteria).

The contract: ``serve_continuous`` may schedule requests however it likes —
any slot count, any chunk size, any arrival order, any EOS placement — and
each request's output must stay **bit-identical** to a solo
``Engine.generate`` call.  Scheduling is an optimization, never a
semantics change (the serving analogue of the paper's claim that lifting
SA utilization must not change the computed function).

Also covers the scheduler's own invariants: a request occupies at most one
slot, every request is served exactly once, and no slot leaks once the
queue drains (``ContinuousScheduler.check_invariants`` runs inside the
serve loop on every iteration; the direct unit tests below drive the
scheduler without jax).

Property tests honor the ``tests/conftest.py`` hypothesis fallback shim.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serve.engine import Engine, ServeConfig
from repro.serve.scheduler import AdmissionQueue, ContinuousScheduler, SlotTable

MAX_NEW = 6

# lazy singleton rather than a pytest fixture: the hypothesis fallback shim
# (tests/conftest.py) wraps @given tests with a zero-arg signature, so
# fixture injection is not available inside property tests
_ENGINE: Engine | None = None


def get_engine() -> Engine:
    global _ENGINE
    if _ENGINE is None:
        arch = configs.get_reduced("qwen1.5-0.5b")
        params = lm.init_params(jax.random.PRNGKey(0), arch.model)
        _ENGINE = Engine(params, arch.model,
                         ServeConfig(max_seq=48, max_new_tokens=MAX_NEW))
    return _ENGINE


@pytest.fixture(scope="module")
def engine():
    return get_engine()


# fixed prompt pool: bounded prefill shapes + solo-generation memo hits
RS = np.random.RandomState(11)
POOL = [RS.randint(0, 100, L).astype(np.int32) for L in (4, 5, 7, 9, 12, 14)]

_SOLO_MEMO: dict = {}


def solo(engine, req: np.ndarray, max_new: int, eos: int) -> np.ndarray:
    """Memoized isolated single-request greedy generation (the oracle)."""
    key = (req.tobytes(), req.shape[0], max_new, eos)
    if key not in _SOLO_MEMO:
        _SOLO_MEMO[key] = engine.generate(
            req[None].astype(np.int32), seed=0,
            request_ids=np.asarray([0]), max_new=max_new, eos_id=eos,
        )[0]
    return _SOLO_MEMO[key]


def test_continuous_matches_solo_mixed_lengths(engine):
    reqs = [POOL[0], POOL[2], POOL[5], POOL[1], POOL[3]]
    outs = engine.serve_continuous(reqs, slots=2, chunk_steps=3, seed=0)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(solo(engine, r, MAX_NEW, -1), outs[i])
    stats = engine.last_serve_stats
    assert stats["n_served"] == len(reqs)
    assert 0.0 < stats["mean_slot_utilization"] <= 1.0


def test_continuous_single_slot_serializes(engine):
    """slots=1 degenerates to sequential serving — same outputs."""
    reqs = [POOL[1], POOL[4], POOL[0]]
    outs = engine.serve_continuous(reqs, slots=1, chunk_steps=2, seed=0)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(solo(engine, r, MAX_NEW, -1), outs[i])


def test_continuous_more_slots_than_requests(engine):
    """Empty slots stay latched and never perturb live rows."""
    reqs = [POOL[3], POOL[2]]
    outs = engine.serve_continuous(reqs, slots=4, chunk_steps=2, seed=0)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(solo(engine, r, MAX_NEW, -1), outs[i])


@hypothesis.settings(max_examples=6, deadline=None)
@hypothesis.given(
    order_seed=st.integers(0, 10_000),
    n_requests=st.integers(1, 5),
    slots=st.integers(1, 3),
    chunk_steps=st.integers(1, 4),
    eos_pos=st.integers(-1, MAX_NEW - 1),   # -1: never-stop
    budget_seed=st.integers(0, 10_000),
)
def test_property_schedule_invariance(order_seed, n_requests, slots,
                                      chunk_steps, eos_pos, budget_seed):
    """Random request sets (lengths, arrival order, per-request budgets,
    EOS placement) x random scheduler shapes (slots, chunk size): every
    per-request output is bit-identical to the isolated greedy generation,
    nobody is dropped, and the slot table drains clean (invariants are
    asserted inside the serve loop)."""
    eng = get_engine()
    rs = np.random.RandomState(order_seed)
    reqs = [POOL[rs.randint(len(POOL))] for _ in range(n_requests)]
    bs = np.random.RandomState(budget_seed)
    budgets = [int(bs.randint(1, MAX_NEW + 1)) for _ in range(n_requests)]
    # EOS id drawn from a real emitted token so latching actually fires
    if eos_pos >= 0:
        probe = solo(eng, reqs[0], MAX_NEW, -1)
        eos = int(probe[min(eos_pos, budgets[0] - 1)])
    else:
        eos = -1
    old = eng.cfg.eos_id
    eng.cfg.eos_id = eos       # eos_id is a traced arg — no retrace
    try:
        outs = eng.serve_continuous(reqs, slots=slots,
                                    chunk_steps=chunk_steps, seed=0,
                                    max_new=budgets)
    finally:
        eng.cfg.eos_id = old
    assert len(outs) == n_requests
    stats = eng.last_serve_stats
    assert stats["n_served"] == n_requests      # all-requests-served
    for i, r in enumerate(reqs):
        expect = solo(eng, r, budgets[i], eos)
        assert outs[i].shape == (budgets[i],)
        np.testing.assert_array_equal(expect, outs[i])


def test_admission_padding_clamped_to_max_seq(engine):
    """A prompt whose pad bucket would exceed max_seq still admits: the
    padded length clamps to max_seq (padding past L is causally invisible)
    — previously the grouped prefill built caches too large to splice.
    Needs a max_seq that is NOT a multiple of the pad bucket."""
    eng = Engine(engine.params, engine.model,
                 ServeConfig(max_seq=30, max_new_tokens=5))
    req = np.asarray(RS.randint(0, 100, 25), np.int32)   # bucket -> 32 > 30
    outs = eng.serve_continuous([req, POOL[0]], slots=2, chunk_steps=2, seed=0)
    np.testing.assert_array_equal(
        eng.generate(req[None].astype(np.int32), seed=0,
                     request_ids=np.asarray([0]))[0], outs[0])
    np.testing.assert_array_equal(
        eng.generate(POOL[0][None].astype(np.int32), seed=0,
                     request_ids=np.asarray([1]))[0], outs[1])


def test_prefill_into_slot_singular_matches_grouped(engine):
    """The batch-1 cache-insert primitive and the grouped admission path
    write identical slot contents and last-token logits."""
    eng = engine
    req = POOL[1]
    L = req.shape[0]
    padded = np.pad(req, (0, 8 - L))[None].astype(np.int32)
    c1 = lm.init_caches(eng.model, 2, eng.cfg.max_seq, eng._dt)
    c2 = lm.init_caches(eng.model, 2, eng.cfg.max_seq, eng._dt)
    last1, c1 = lm.prefill_into_slot(
        eng.params, eng.model, jax.numpy.asarray(padded),
        jax.numpy.int32(L), jax.numpy.int32(1), c1, eng.cfg.max_seq, eng._dt)
    last2, c2 = lm.prefill_into_slots(
        eng.params, eng.model, jax.numpy.asarray(padded),
        jax.numpy.asarray([L]), jax.numpy.asarray([1]), c2,
        eng.cfg.max_seq, eng._dt)
    np.testing.assert_array_equal(np.asarray(last1), np.asarray(last2[0]))
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# scheduler unit tests (host-side, no jax)
# ---------------------------------------------------------------------------


def test_slot_table_admit_retire_cycle():
    t = SlotTable(2)
    assert t.free_slots() == [0, 1]
    t.admit(0, request_id=7, pos=5, remaining=3)
    assert t.free_slots() == [1] and t.live_slots() == [0]
    with pytest.raises(AssertionError):
        t.admit(0, request_id=8, pos=1, remaining=1)   # double-occupancy
    assert t.retire(0) == 7
    assert t.free_slots() == [0, 1]
    with pytest.raises(AssertionError):
        t.retire(0)                                    # double-free


def test_admission_queue_fifo():
    q = AdmissionQueue([3, 1, 2])
    assert [q.pop(), q.pop(), q.pop()] == [3, 1, 2]
    assert not q


def test_scheduler_chunk_bookkeeping_and_utilization():
    s = ContinuousScheduler(n_slots=2, request_ids=[0, 1, 2])
    # one burst admits the first two into distinct slots
    ready = s.admit_ready()
    assert [slot for slot, _ in ready] == [0, 1]
    for slot, rid in ready:
        assert not s.confirm_admit(slot, rid, pos=4, remaining=3, eos_hit=False)
    assert s.admit_ready() == []                       # table full
    # chunk of 2: nobody hits EOS; both still owe 1 token
    res = s.complete_chunk(2, eos_hits=[False, False])
    assert [(b, rid, k, fin) for b, rid, k, fin in res] == [
        (0, 0, 2, False), (1, 1, 2, False)]
    # chunk of 2: both exhaust their budgets (1 kept, 1 dead step each)
    res = s.complete_chunk(2, eos_hits=[False, False])
    assert all(fin for *_, fin in res)
    for b, rid, _, _ in res:
        s.retire(b)
    # request 2 fits now; EOS ends it on the first chunk step — its
    # second (pad) emission counts as waste via eos_steps
    (slot, rid), = s.admit_ready()
    assert rid == 2
    s.confirm_admit(slot, rid, pos=4, remaining=3, eos_hit=False)
    (b, rid, kept, fin), = s.complete_chunk(
        2, eos_hits=[True, False], eos_steps=[0, 2])
    assert fin and s.retire(b) == 2
    s.check_invariants()
    assert sorted(s.served) == [0, 1, 2]
    # utilization: kept token-steps over slots x steps capacity
    st_ = s.stats()
    assert st_["total_token_steps"] == 3 * 2 * 2
    assert st_["useful_token_steps"] == 2 + 2 + 1 + 1 + 1
    assert 0 < st_["mean_slot_utilization"] < 1


def test_scheduler_detects_slot_leak():
    s = ContinuousScheduler(n_slots=1, request_ids=[0])
    (slot, rid), = s.admit_ready()
    s.confirm_admit(slot, rid, pos=1, remaining=5, eos_hit=False)
    s.served.append(rid)            # lie: served while still occupying a slot
    with pytest.raises(AssertionError):
        s.check_invariants()


def test_scheduler_immediate_finish_on_admit():
    """Budget-1 (or first-token-EOS) requests finish at admission and the
    slot is reusable without ever entering a chunk."""
    s = ContinuousScheduler(n_slots=1, request_ids=[0, 1])
    (slot, rid), = s.admit_ready()
    assert s.confirm_admit(slot, rid, pos=3, remaining=0, eos_hit=False)
    s.retire(slot)
    (slot, rid), = s.admit_ready()
    assert rid == 1
    assert s.confirm_admit(slot, rid, pos=3, remaining=4, eos_hit=True)
    s.retire(slot)
    s.check_invariants()
    assert s.served == [0, 1]
