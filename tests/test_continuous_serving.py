"""Continuous-batching equivalence battery (PR acceptance criteria).

The contract: ``serve_continuous`` may schedule requests however it likes —
any slot count, any chunk size, any arrival order, any EOS placement — and
each request's output must stay **bit-identical** to a solo
``Engine.generate`` call.  Scheduling is an optimization, never a
semantics change (the serving analogue of the paper's claim that lifting
SA utilization must not change the computed function).

Also covers the scheduler's own invariants: a request occupies at most one
slot, every request is served exactly once, and no slot leaks once the
queue drains (``ContinuousScheduler.check_invariants`` runs inside the
serve loop on every iteration; the direct unit tests below drive the
scheduler without jax).

Property tests honor the ``tests/conftest.py`` hypothesis fallback shim.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serve.engine import Engine, ServeConfig
from repro.serve.scheduler import AdmissionQueue, ContinuousScheduler, SlotTable

MAX_NEW = 6

# lazy singleton rather than a pytest fixture: the hypothesis fallback shim
# (tests/conftest.py) wraps @given tests with a zero-arg signature, so
# fixture injection is not available inside property tests
_ENGINE: Engine | None = None


def get_engine() -> Engine:
    global _ENGINE
    if _ENGINE is None:
        arch = configs.get_reduced("qwen1.5-0.5b")
        params = lm.init_params(jax.random.PRNGKey(0), arch.model)
        _ENGINE = Engine(params, arch.model,
                         ServeConfig(max_seq=48, max_new_tokens=MAX_NEW))
    return _ENGINE


@pytest.fixture(scope="module")
def engine():
    return get_engine()


# fixed prompt pool: bounded prefill shapes + solo-generation memo hits
RS = np.random.RandomState(11)
POOL = [RS.randint(0, 100, L).astype(np.int32) for L in (4, 5, 7, 9, 12, 14)]

_SOLO_MEMO: dict = {}


def solo(engine, req: np.ndarray, max_new: int, eos: int) -> np.ndarray:
    """Memoized isolated single-request greedy generation (the oracle)."""
    key = (req.tobytes(), req.shape[0], max_new, eos)
    if key not in _SOLO_MEMO:
        _SOLO_MEMO[key] = engine.generate(
            req[None].astype(np.int32), seed=0,
            request_ids=np.asarray([0]), max_new=max_new, eos_id=eos,
        )[0]
    return _SOLO_MEMO[key]


def test_continuous_matches_solo_mixed_lengths(engine):
    reqs = [POOL[0], POOL[2], POOL[5], POOL[1], POOL[3]]
    outs = engine.serve_continuous(reqs, slots=2, chunk_steps=3, seed=0)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(solo(engine, r, MAX_NEW, -1), outs[i])
    stats = engine.last_serve_stats
    assert stats["n_served"] == len(reqs)
    assert 0.0 < stats["mean_slot_utilization"] <= 1.0


def test_continuous_single_slot_serializes(engine):
    """slots=1 degenerates to sequential serving — same outputs."""
    reqs = [POOL[1], POOL[4], POOL[0]]
    outs = engine.serve_continuous(reqs, slots=1, chunk_steps=2, seed=0)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(solo(engine, r, MAX_NEW, -1), outs[i])


def test_continuous_more_slots_than_requests(engine):
    """Empty slots stay latched and never perturb live rows."""
    reqs = [POOL[3], POOL[2]]
    outs = engine.serve_continuous(reqs, slots=4, chunk_steps=2, seed=0)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(solo(engine, r, MAX_NEW, -1), outs[i])


@hypothesis.settings(max_examples=6, deadline=None)
@hypothesis.given(
    order_seed=st.integers(0, 10_000),
    n_requests=st.integers(1, 5),
    slots=st.integers(1, 3),
    chunk_steps=st.integers(1, 4),
    eos_pos=st.integers(-1, MAX_NEW - 1),   # -1: never-stop
    budget_seed=st.integers(0, 10_000),
)
def test_property_schedule_invariance(order_seed, n_requests, slots,
                                      chunk_steps, eos_pos, budget_seed):
    """Random request sets (lengths, arrival order, per-request budgets,
    EOS placement) x random scheduler shapes (slots, chunk size): every
    per-request output is bit-identical to the isolated greedy generation,
    nobody is dropped, and the slot table drains clean (invariants are
    asserted inside the serve loop)."""
    eng = get_engine()
    rs = np.random.RandomState(order_seed)
    reqs = [POOL[rs.randint(len(POOL))] for _ in range(n_requests)]
    bs = np.random.RandomState(budget_seed)
    budgets = [int(bs.randint(1, MAX_NEW + 1)) for _ in range(n_requests)]
    # EOS id drawn from a real emitted token so latching actually fires
    if eos_pos >= 0:
        probe = solo(eng, reqs[0], MAX_NEW, -1)
        eos = int(probe[min(eos_pos, budgets[0] - 1)])
    else:
        eos = -1
    old = eng.cfg.eos_id
    eng.cfg.eos_id = eos       # eos_id is a traced arg — no retrace
    try:
        outs = eng.serve_continuous(reqs, slots=slots,
                                    chunk_steps=chunk_steps, seed=0,
                                    max_new=budgets)
    finally:
        eng.cfg.eos_id = old
    assert len(outs) == n_requests
    stats = eng.last_serve_stats
    assert stats["n_served"] == n_requests      # all-requests-served
    for i, r in enumerate(reqs):
        expect = solo(eng, r, budgets[i], eos)
        assert outs[i].shape == (budgets[i],)
        np.testing.assert_array_equal(expect, outs[i])


# ---------------------------------------------------------------------------
# paged KV cache (block pool + prefix cache + preemption) — same contract
# ---------------------------------------------------------------------------

# memoized per pool shape: a new Engine re-jits its programs, so the sweep
# reuses engines across examples (params shared with the dense singleton)
_PAGED_ENGINES: dict = {}


def get_paged_engine(block_size: int, pool_blocks: int) -> Engine:
    key = (block_size, pool_blocks)
    if key not in _PAGED_ENGINES:
        base = get_engine()
        _PAGED_ENGINES[key] = Engine(
            base.params, base.model,
            ServeConfig(max_seq=48, max_new_tokens=MAX_NEW, paged=True,
                        block_size=block_size, pool_blocks=pool_blocks),
        )
    return _PAGED_ENGINES[key]


def _assert_pool_drained(eng: Engine) -> None:
    """Zero leaked blocks + balanced refcounts after drain: every in-use
    block is prefix-cache-held at refcount exactly 1, and flushing the
    prefix cache returns the pool to fully free."""
    pool = eng._last_pool
    pool.check_balanced(n_live_requests=0)
    st = eng.last_serve_stats["paged"]
    assert st["blocks_in_use"] == st["blocks_cache_held"]
    if eng._last_prefix is not None:
        eng._last_prefix.flush(pool)
    assert pool.free_count() == pool.usable and pool.in_use() == 0
    pool.check_balanced(n_live_requests=0)


@hypothesis.settings(max_examples=5, deadline=None)
@hypothesis.given(
    order_seed=st.integers(0, 10_000),
    n_requests=st.integers(1, 5),
    slots=st.integers(1, 3),
    chunk_steps=st.integers(1, 3),
    block_size=st.sampled_from([4, 6, 8]),
    pool_slack=st.integers(0, 3),           # blocks beyond the 1-request
                                            # minimum: small -> preemption
    shared_prefix=st.booleans(),
    eos_pos=st.integers(-1, MAX_NEW - 1),
    budget_seed=st.integers(0, 10_000),
)
def test_property_paged_schedule_invariance(order_seed, n_requests, slots,
                                            chunk_steps, block_size,
                                            pool_slack, shared_prefix,
                                            eos_pos, budget_seed):
    """The tentpole acceptance sweep: random request sets (optionally
    sharing a long prompt prefix, so the prefix cache actually hits) x
    random block sizes x pools barely larger than a single request's
    worst-case footprint (so admission stalls and preempt-youngest fire) —
    every output stays bit-identical to the isolated dense generation, and
    the block pool drains with zero leaks and balanced refcounts."""
    from repro.serve.kv_pool import worst_case_blocks

    eng_d = get_engine()
    rs = np.random.RandomState(order_seed)
    reqs = [POOL[rs.randint(len(POOL))] for _ in range(n_requests)]
    if shared_prefix:
        # common 9-token prefix: at block_size 4 that is 2 shareable full
        # blocks; lengths stay <= 23 + MAX_NEW < 48
        common = RS.randint(0, 100, 9).astype(np.int32)
        reqs = [np.concatenate([common, r]) for r in reqs]
    bs_ = np.random.RandomState(budget_seed)
    budgets = [int(bs_.randint(1, MAX_NEW + 1)) for _ in range(n_requests)]
    if eos_pos >= 0:
        probe = solo(eng_d, reqs[0], MAX_NEW, -1)
        eos = int(probe[min(eos_pos, budgets[0] - 1)])
    else:
        eos = -1
    wmax = max(
        worst_case_blocks(r.shape[0], m, chunk_steps, block_size, 48)
        for r, m in zip(reqs, budgets)
    )
    eng_p = get_paged_engine(block_size, wmax + pool_slack + 1)
    old_d, old_p = eng_d.cfg.eos_id, eng_p.cfg.eos_id
    eng_d.cfg.eos_id = eng_p.cfg.eos_id = eos
    try:
        outs = eng_p.serve_continuous(reqs, slots=slots,
                                      chunk_steps=chunk_steps, seed=0,
                                      max_new=budgets)
        for i, r in enumerate(reqs):
            np.testing.assert_array_equal(
                solo(eng_d, r, budgets[i], eos), outs[i]
            )
    finally:
        eng_d.cfg.eos_id, eng_p.cfg.eos_id = old_d, old_p
    assert eng_p.last_serve_stats["n_served"] == n_requests
    _assert_pool_drained(eng_p)


def test_paged_forced_preemption_still_bit_identical(engine):
    """A pool barely above one request's footprint with several slots live
    MUST preempt — and preemption-with-recompute regenerates the same
    tokens, so outputs stay bit-equal to solo generation."""
    eng_p = get_paged_engine(4, 8)            # 7 usable blocks
    reqs = [POOL[3], POOL[4], POOL[5], POOL[0]]
    outs = eng_p.serve_continuous(reqs, slots=3, chunk_steps=2, seed=0)
    assert eng_p.last_serve_stats["n_preemptions"] > 0
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(solo(engine, r, MAX_NEW, -1), outs[i])
    _assert_pool_drained(eng_p)


def test_paged_prefix_hits_skip_prefill_work(engine):
    """Identical prompts served paged: later admissions reuse the first
    request's blocks (prefill_tokens_saved > 0) and still match solo."""
    eng_p = get_paged_engine(4, 40)
    reqs = [POOL[5]] * 4
    outs = eng_p.serve_continuous(reqs, slots=2, chunk_steps=2, seed=0)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(solo(engine, r, MAX_NEW, -1), outs[i])
    st = eng_p.last_serve_stats["paged"]
    assert st["prefix_hit_blocks"] > 0 and st["prefill_tokens_saved"] > 0
    _assert_pool_drained(eng_p)


def test_paged_step_read_path_bit_identical(engine):
    """paged_read='step' (per-token block-table reads — the shape a fused
    TPU paged-attention kernel executes) matches solo generation and the
    default shadow path, including under forced preemption; unknown
    paged_read values are rejected up front."""
    eng_s = Engine(engine.params, engine.model,
                   ServeConfig(max_seq=48, max_new_tokens=MAX_NEW, paged=True,
                               block_size=4, pool_blocks=8,
                               paged_read="step"))
    reqs = [POOL[3], POOL[4], POOL[5], POOL[0]]
    outs = eng_s.serve_continuous(reqs, slots=3, chunk_steps=2, seed=0)
    assert eng_s.last_serve_stats["n_preemptions"] > 0
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(solo(engine, r, MAX_NEW, -1), outs[i])
    _assert_pool_drained(eng_s)
    eng_bad = Engine(engine.params, engine.model,
                     ServeConfig(max_seq=48, max_new_tokens=MAX_NEW,
                                 paged=True, block_size=4,
                                 paged_read="Shadow"))
    with pytest.raises(ValueError, match="paged_read"):
        eng_bad.serve_continuous([POOL[0]], slots=1, chunk_steps=2)


def test_scheduler_preempt_requeues_at_head():
    s = ContinuousScheduler(n_slots=2, request_ids=[0, 1, 2])
    for slot, rid in s.admit_ready():
        s.confirm_admit(slot, rid, pos=4, remaining=3, eos_hit=False)
    assert s.youngest_live_slot() == 1        # rid 1 admitted last
    assert s.preempt(1) == 1
    assert s.n_preemptions == 1
    # head-of-queue: rid 1 re-admits before rid 2
    (slot, rid), = s.admit_ready()
    assert rid == 1
    s.confirm_admit(slot, rid, pos=4, remaining=3, eos_hit=False)
    s.check_invariants()


def test_admission_padding_clamped_to_max_seq(engine):
    """A prompt whose pad bucket would exceed max_seq still admits: the
    padded length clamps to max_seq (padding past L is causally invisible)
    — previously the grouped prefill built caches too large to splice.
    Needs a max_seq that is NOT a multiple of the pad bucket."""
    eng = Engine(engine.params, engine.model,
                 ServeConfig(max_seq=30, max_new_tokens=5))
    req = np.asarray(RS.randint(0, 100, 25), np.int32)   # bucket -> 32 > 30
    outs = eng.serve_continuous([req, POOL[0]], slots=2, chunk_steps=2, seed=0)
    np.testing.assert_array_equal(
        eng.generate(req[None].astype(np.int32), seed=0,
                     request_ids=np.asarray([0]))[0], outs[0])
    np.testing.assert_array_equal(
        eng.generate(POOL[0][None].astype(np.int32), seed=0,
                     request_ids=np.asarray([1]))[0], outs[1])


def test_prefill_into_slot_singular_matches_grouped(engine):
    """The batch-1 cache-insert primitive and the grouped admission path
    write identical slot contents and last-token logits."""
    eng = engine
    req = POOL[1]
    L = req.shape[0]
    padded = np.pad(req, (0, 8 - L))[None].astype(np.int32)
    c1 = lm.init_caches(eng.model, 2, eng.cfg.max_seq, eng._dt)
    c2 = lm.init_caches(eng.model, 2, eng.cfg.max_seq, eng._dt)
    last1, c1 = lm.prefill_into_slot(
        eng.params, eng.model, jax.numpy.asarray(padded),
        jax.numpy.int32(L), jax.numpy.int32(1), c1, eng.cfg.max_seq, eng._dt)
    last2, c2 = lm.prefill_into_slots(
        eng.params, eng.model, jax.numpy.asarray(padded),
        jax.numpy.asarray([L]), jax.numpy.asarray([1]), c2,
        eng.cfg.max_seq, eng._dt)
    np.testing.assert_array_equal(np.asarray(last1), np.asarray(last2[0]))
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# scheduler unit tests (host-side, no jax)
# ---------------------------------------------------------------------------


def test_slot_table_admit_retire_cycle():
    t = SlotTable(2)
    assert t.free_slots() == [0, 1]
    t.admit(0, request_id=7, pos=5, remaining=3)
    assert t.free_slots() == [1] and t.live_slots() == [0]
    with pytest.raises(AssertionError):
        t.admit(0, request_id=8, pos=1, remaining=1)   # double-occupancy
    assert t.retire(0) == 7
    assert t.free_slots() == [0, 1]
    with pytest.raises(AssertionError):
        t.retire(0)                                    # double-free


def test_admission_queue_fifo():
    q = AdmissionQueue([3, 1, 2])
    assert [q.pop(), q.pop(), q.pop()] == [3, 1, 2]
    assert not q


def test_scheduler_chunk_bookkeeping_and_utilization():
    s = ContinuousScheduler(n_slots=2, request_ids=[0, 1, 2])
    # one burst admits the first two into distinct slots
    ready = s.admit_ready()
    assert [slot for slot, _ in ready] == [0, 1]
    for slot, rid in ready:
        assert not s.confirm_admit(slot, rid, pos=4, remaining=3, eos_hit=False)
    assert s.admit_ready() == []                       # table full
    # chunk of 2: nobody hits EOS; both still owe 1 token
    res = s.complete_chunk(2, eos_hits=[False, False])
    assert [(b, rid, k, fin) for b, rid, k, fin in res] == [
        (0, 0, 2, False), (1, 1, 2, False)]
    # chunk of 2: both exhaust their budgets (1 kept, 1 dead step each)
    res = s.complete_chunk(2, eos_hits=[False, False])
    assert all(fin for *_, fin in res)
    for b, rid, _, _ in res:
        s.retire(b)
    # request 2 fits now; EOS ends it on the first chunk step — its
    # second (pad) emission counts as waste via eos_steps
    (slot, rid), = s.admit_ready()
    assert rid == 2
    s.confirm_admit(slot, rid, pos=4, remaining=3, eos_hit=False)
    (b, rid, kept, fin), = s.complete_chunk(
        2, eos_hits=[True, False], eos_steps=[0, 2])
    assert fin and s.retire(b) == 2
    s.check_invariants()
    assert sorted(s.served) == [0, 1, 2]
    # utilization: kept token-steps over slots x steps capacity
    st_ = s.stats()
    assert st_["total_token_steps"] == 3 * 2 * 2
    assert st_["useful_token_steps"] == 2 + 2 + 1 + 1 + 1
    assert 0 < st_["mean_slot_utilization"] < 1


def test_scheduler_detects_slot_leak():
    s = ContinuousScheduler(n_slots=1, request_ids=[0])
    (slot, rid), = s.admit_ready()
    s.confirm_admit(slot, rid, pos=1, remaining=5, eos_hit=False)
    s.served.append(rid)            # lie: served while still occupying a slot
    with pytest.raises(AssertionError):
        s.check_invariants()


# ---------------------------------------------------------------------------
# retrace sentinel regression tests (kanlint runtime sentinel; the compile
# counts below are the documented trace budgets — a new program here means
# a shape leaked into a traced argument or a static argnum changed)
# ---------------------------------------------------------------------------

RS2 = np.random.RandomState(23)


def fresh_engine(**cfg_kw) -> Engine:
    """Engine with a virgin jit cache (shares params with the singleton)."""
    base = get_engine()
    return Engine(base.params, base.model,
                  ServeConfig(max_seq=48, max_new_tokens=MAX_NEW, **cfg_kw))


def test_retrace_eos_sweep_reuses_decode_program(assert_trace_budget):
    """``eos_id`` is a traced scalar: sweeping it across generate() calls —
    including the never-stop sentinel -1 — must not retrace the decode scan
    (with ``lengths`` given, row positions are per-row for every eos value,
    so the abstract signature is eos-invariant).  PR 3 documented this
    contract; the sentinel now machine-checks it."""
    eng = fresh_engine()
    prompts = POOL[0][None].astype(np.int32)
    lens = np.asarray([POOL[0].shape[0]])

    def gen(eos):
        return eng.generate(prompts, seed=0, lengths=lens,
                            request_ids=np.asarray([0]),
                            max_new=MAX_NEW, eos_id=eos)

    probe = gen(-1)
    live = int(probe[0, 1])          # a token the model really emits
    outs = {eos: gen(eos) for eos in (0, 5, live, -1)}
    assert_trace_budget(eng, {"prefill": 1, "decode_chunk": 1,
                              "keys_first": 1})
    # and the sweep actually exercised distinct eos behavior: latching on
    # a truly-emitted token pads the tail, eos=-1 never latches
    assert not np.array_equal(outs[live], outs[-1])
    np.testing.assert_array_equal(outs[-1], probe)


def test_retrace_repeat_mix_compiles_nothing_new():
    """Re-serving a workload with the same prompt lengths (fresh token
    content, different seed) admits through the same pad buckets and must
    not compile a single new program for ANY entry point."""
    eng = fresh_engine()
    reqs = [POOL[0], POOL[2], POOL[5], POOL[1], POOL[3]]
    eng.serve_continuous(reqs, slots=2, chunk_steps=3, seed=0)
    before = {n: s["programs"] for n, s in eng.compiles.snapshot().items()}
    fresh = [RS2.randint(0, 100, r.shape[0]).astype(np.int32) for r in reqs]
    eng.serve_continuous(fresh, slots=2, chunk_steps=3, seed=1)
    after = {n: s["programs"] for n, s in eng.compiles.snapshot().items()}
    assert after == before, (before, after)


def test_retrace_continuous_battery_documented_budget(assert_trace_budget):
    """The dense continuous-serving battery compiles exactly the documented
    program count: ONE decode_chunk program (slot count and chunk size are
    fixed; eos/budgets are traced), one admission-prefill program per
    distinct (group size, pad bucket) pair, and keys_first per batch shape.
    ``last_serve_stats["compiles"]`` exports the same snapshot."""
    eng = fresh_engine()
    reqs = [POOL[0], POOL[2], POOL[5], POOL[1], POOL[3]]
    eng.serve_continuous(reqs, slots=2, chunk_steps=3, seed=0)
    # cache_init is counted only on mesh runs (eager off-mesh), hence 0 here
    assert_trace_budget(eng, {"decode_chunk": 1, "cache_init": 0})
    snap = eng.last_serve_stats["compiles"]
    assert snap == eng.compiles.snapshot()
    assert snap["decode_chunk"]["traces"] == 1


def test_retrace_paged_battery_documented_budget(assert_trace_budget):
    """Paged serving adds the paged programs (gather_views, prefill_pages,
    writeback_chunk) but keeps the same one-decode-program contract."""
    eng = fresh_engine(paged=True, block_size=4, pool_blocks=40)
    reqs = [POOL[0], POOL[2], POOL[5], POOL[1], POOL[3]]
    eng.serve_continuous(reqs, slots=2, chunk_steps=3, seed=0)
    assert_trace_budget(eng, {"decode_chunk": 1, "gather_views": 1,
                              "writeback_chunk": 1})
    snap = eng.last_serve_stats["compiles"]
    assert snap["decode_chunk"]["programs"] == 1


def test_scheduler_immediate_finish_on_admit():
    """Budget-1 (or first-token-EOS) requests finish at admission and the
    slot is reusable without ever entering a chunk."""
    s = ContinuousScheduler(n_slots=1, request_ids=[0, 1])
    (slot, rid), = s.admit_ready()
    assert s.confirm_admit(slot, rid, pos=3, remaining=0, eos_hit=False)
    s.retire(slot)
    (slot, rid), = s.admit_ready()
    assert rid == 1
    assert s.confirm_admit(slot, rid, pos=3, remaining=4, eos_hit=True)
    s.retire(slot)
    s.check_invariants()
    assert s.served == [0, 1]
