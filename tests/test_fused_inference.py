"""One-pass fused inference tests (epilogue-fused kernels, autotuned tiles,
scan decode).

Covers the PR acceptance criteria:

* ``kan_layer_apply(..., method="fused")`` computes spline + base in a
  SINGLE ``pallas_call`` and matches ``dense`` within 1e-4 (fp32) / 2e-2
  (bf16) on randomized shapes including non-tile-multiple BS/K/N;
* the int8 kernel's fused dequant epilogue matches the reference quantized
  path exactly;
* the engine's scan decode is bit-identical to the unrolled loop decode;
* the autotuner cache round-trips and ops.py consults it.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kan_layer as kl
from repro.core import quantization as q
from repro.core.bspline import SplineGrid


def _layer(G, P, K, N, seed=0, base=True, dtype=jnp.float32):
    g = SplineGrid(-1.0, 1.0, G, P)
    cfg = kl.KANLayerConfig(K, N, g, base=base)
    params = kl.init_kan_layer(jax.random.PRNGKey(seed), cfg, dtype)
    return g, params


class TestFusedWithBase:
    # non-tile-multiple BS/K/N on purpose (the kernel pads internally)
    SHAPES = [(5, 3, 40, 24, 16), (5, 3, 100, 37, 50), (3, 2, 33, 5, 7),
              (10, 3, 17, 20, 10), (3, 3, 1, 22, 60)]

    @pytest.mark.parametrize("G,P,BS,K,N", SHAPES)
    def test_fused_base_matches_dense_fp32(self, G, P, BS, K, N):
        g, params = _layer(G, P, K, N)
        x = jnp.asarray(
            np.random.RandomState(BS + K).uniform(-1, 1, (BS, K)).astype(np.float32)
        )
        a = kl.kan_layer_apply(params, x, g, "dense")
        b = kl.kan_layer_apply(params, x, g, "fused")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("G,P,BS,K,N", SHAPES[:3])
    def test_fused_base_matches_dense_bf16(self, G, P, BS, K, N):
        g, params = _layer(G, P, K, N)
        x32 = jnp.asarray(
            np.random.RandomState(BS).uniform(-1, 1, (BS, K)).astype(np.float32)
        )
        ref = kl.kan_layer_apply(params, x32, g, "dense")
        p16 = jax.tree.map(lambda a: a.astype(jnp.bfloat16), params)
        got = kl.kan_layer_apply(p16, x32.astype(jnp.bfloat16), g, "fused")
        scale = float(jnp.abs(ref).max()) + 1e-9
        err = float(jnp.abs(got.astype(jnp.float32) - ref).max()) / scale
        assert err < 2e-2, err

    def test_fused_without_base(self):
        g, params = _layer(5, 3, 24, 16, base=False)
        assert "base_w" not in params
        x = jnp.asarray(
            np.random.RandomState(1).uniform(-1, 1, (40, 24)).astype(np.float32)
        )
        a = kl.kan_layer_apply(params, x, g, "dense")
        b = kl.kan_layer_apply(params, x, g, "fused")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)

    def test_randomized_shapes(self):
        rs = np.random.RandomState(42)
        for _ in range(6):
            G, P = int(rs.randint(2, 9)), int(rs.randint(1, 4))
            BS, K, N = (int(rs.randint(1, 150)), int(rs.randint(1, 60)),
                        int(rs.randint(1, 80)))
            g, params = _layer(G, P, K, N, seed=BS)
            x = jnp.asarray(rs.uniform(-1, 1, (BS, K)).astype(np.float32))
            a = kl.kan_layer_apply(params, x, g, "dense")
            b = kl.kan_layer_apply(params, x, g, "fused")
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4,
                err_msg=f"G={G} P={P} BS={BS} K={K} N={N}",
            )

    def test_single_pallas_call(self):
        """Spline + base in ONE kernel: no separate base GEMM."""
        g, params = _layer(5, 3, 24, 16)
        x = jnp.zeros((8, 24), jnp.float32)
        jaxpr = str(jax.make_jaxpr(
            lambda p, x: kl.kan_layer_apply(p, x, g, "fused")
        )(params, x))
        assert jaxpr.count("pallas_call") == 1, jaxpr.count("pallas_call")

    def test_auto_method_resolves(self):
        assert kl.resolve_inference_method("tpu") == "fused"
        assert kl.resolve_inference_method("cpu") == "compact"
        g, params = _layer(5, 3, 8, 6)
        x = jnp.zeros((4, 8), jnp.float32)
        y = kl.kan_layer_apply(params, x, g, "auto")
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(kl.kan_layer_apply(params, x, g, "dense")),
            atol=1e-5,
        )


class TestInt8FusedDequant:
    @pytest.mark.parametrize("G,P,BS,K,N", [(5, 3, 40, 24, 16),
                                            (5, 3, 100, 37, 50),
                                            (3, 2, 33, 5, 7)])
    def test_fused_dequant_matches_reference(self, G, P, BS, K, N):
        """Kernel with fused dequant epilogue == reference quantized path
        (same int32 accumulator, same per-channel multiply)."""
        g = SplineGrid(-1.0, 1.0, G, P)
        cfg = kl.KANLayerConfig(K, N, g)
        params = kl.init_kan_layer(jax.random.PRNGKey(0), cfg)
        x = jnp.asarray(
            np.random.RandomState(7).uniform(-1, 1, (BS, K)).astype(np.float32)
        )
        qlayer = q.quantize_kan_layer(params, g)
        ref = q.quantized_kan_forward(qlayer, x)
        got = q.quantized_kan_forward_fused(qlayer, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)

    def test_nondefault_lut_scale_supported(self):
        """The paper's scale 192 table: the kernel must infer the scale from
        a concrete table and stay bit-exact vs the oracle."""
        from repro.kernels import ops, ref

        g = SplineGrid(-1.0, 1.0, 5, 3)
        rs = np.random.RandomState(3)
        x = jnp.asarray(rs.uniform(-1, 1, (33, 10)).astype(np.float32))
        qg = q.QuantizedGrid.make(g)
        xq = qg.x_quant.quantize(x)
        lut192 = jnp.asarray(q.build_lut_u8(g.P, 256, scale=192))
        cq = jnp.asarray(rs.randint(-127, 128, (10, g.n_basis, 7)).astype(np.int8))
        y = ops.kan_int8_gemm(xq, lut192, cq, g, bb=32, bn=32, bk=8)
        yr = ref.ref_kan_gemm_int8(xq, cq, lut192, g)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
        with pytest.raises(ValueError):  # arbitrary tables stay rejected
            ops.kan_int8_gemm(xq, lut192.at[0, 0].add(3), cq, g)

    def test_fused_dequant_emits_input_dtype(self):
        g = SplineGrid(-1.0, 1.0, 5, 3)
        params = kl.init_kan_layer(
            jax.random.PRNGKey(0), kl.KANLayerConfig(8, 6, g)
        )
        qlayer = q.quantize_kan_layer(params, g)
        x = jnp.zeros((4, 8), jnp.bfloat16)
        assert q.quantized_kan_forward_fused(qlayer, x).dtype == jnp.bfloat16


class TestScanDecode:
    def _engine(self, temperature, decode_impl):
        from repro import configs
        from repro.models import lm
        from repro.serve.engine import Engine, ServeConfig

        arch = configs.get_reduced("qwen1.5-0.5b")
        params = lm.init_params(jax.random.PRNGKey(0), arch.model)
        return Engine(params, arch.model, ServeConfig(
            max_seq=40, max_new_tokens=6, temperature=temperature,
            decode_impl=decode_impl,
        ))

    @pytest.mark.parametrize("temperature", [0.0, 0.8])
    def test_scan_equals_loop(self, temperature):
        """The compiled lax.scan decode must reproduce the unrolled python
        loop token-for-token (greedy AND sampled: same key sequence)."""
        prompts = np.random.RandomState(0).randint(0, 100, (2, 5)).astype(np.int32)
        a = self._engine(temperature, "scan").generate(prompts, seed=3)
        b = self._engine(temperature, "loop").generate(prompts, seed=3)
        np.testing.assert_array_equal(a, b)

    def test_serve_requests_buckets_by_length(self):
        """Mixed-length requests: results come back in input order and each
        bucket pads only to its own max."""
        from repro import configs
        from repro.models import lm
        from repro.serve.engine import Engine, ServeConfig

        arch = configs.get_reduced("qwen1.5-0.5b")
        params = lm.init_params(jax.random.PRNGKey(1), arch.model)
        eng = Engine(params, arch.model, ServeConfig(max_seq=40, max_new_tokens=4))
        rs = np.random.RandomState(1)
        reqs = [rs.randint(0, 100, L).astype(np.int32) for L in (12, 3, 12, 4, 3)]
        outs = eng.serve_requests(reqs, batch_size=2)
        assert len(outs) == 5 and all(o.shape == (4,) for o in outs)
        # per-request result must match generating that request alone in a
        # same-length batch (bucketing must not mix lengths into padding)
        solo = eng.generate(np.stack([reqs[1], reqs[4]]).astype(np.int32), seed=0)
        np.testing.assert_array_equal(outs[1], solo[0])


class TestAutotune:
    def test_cache_roundtrip(self, tmp_path, monkeypatch):
        from repro.kernels import autotune as tune

        monkeypatch.setenv(tune.CACHE_ENV, str(tmp_path / "at.json"))
        key = tune.problem_key("fused", 64, 16, 32, 8, jnp.float32, "cpu")
        assert tune._load_cache() == {}
        tune._save_cache({key: {"tiles": [32, 32, 8], "us": 1.0}})
        got = tune.get_tiles("fused", 64, 16, 32, 8, jnp.float32, "cpu")
        assert got == (32, 32, 8)

    def test_heuristic_clamps_to_problem(self):
        from repro.kernels import autotune as tune

        bb, bn, bk = tune.get_tiles("fused", 3, 5, 7, 8, jnp.float32, "cpu")
        assert bb <= 8 and bk <= 5  # no 128-padding for tiny problems

    def test_autotune_records_winner(self, tmp_path, monkeypatch):
        from repro.kernels import autotune as tune
        from repro.kernels import ops as kops

        monkeypatch.setenv(tune.CACHE_ENV, str(tmp_path / "at.json"))
        g = SplineGrid(-1.0, 1.0, 5, 3)
        params = kl.init_kan_layer(
            jax.random.PRNGKey(0), kl.KANLayerConfig(16, 32, g)
        )
        x = jnp.asarray(
            np.random.RandomState(0).uniform(-1, 1, (64, 16)).astype(np.float32)
        )
        rep = tune.autotune(
            "fused",
            lambda bb, bn, bk: kops.kan_fused_gemm(
                x, params["coeff"], g, base_w=params["base_w"],
                bb=bb, bn=bn, bk=bk,
            ),
            64, 16, 32, g.n_basis, iters=1,
            candidates=[(32, 32, 8), (64, 32, 16)],
        )
        assert tuple(rep["tiles"]) in {(32, 32, 8), (64, 32, 16)}
        assert os.path.exists(str(tmp_path / "at.json"))
        # ops.py must now consult the recorded winner when tiles unspecified
        assert tune.get_tiles(
            "fused", 64, 16, 32, g.n_basis, x.dtype, jax.default_backend()
        ) == tuple(rep["tiles"])
