"""Batched serving demo: serve a small kanformer with batched requests
through the prefill+decode engine.

    PYTHONPATH=src python examples/serve_kan.py                      # static
    PYTHONPATH=src python examples/serve_kan.py --engine continuous  # slots
    PYTHONPATH=src python examples/serve_kan.py --engine continuous \\
        --shared-prefix                                   # paged + prefix hits

``--engine static`` drains length-sorted fixed buckets;
``--engine continuous`` recycles batch slots the moment a request finishes
(EOS or budget) — the software analogue of the paper's never-idle PEs.
``--shared-prefix`` switches to the paged KV cache and builds a
system-prompt-heavy workload (every request shares a long prefix, unique
short suffixes): the prefix cache prefillls the shared blocks once and
every later admission reuses them, so the demo prints how many prefill
tokens the block pool saved (DESIGN.md §3b).  ``--mesh DxM`` serves on a
(data, model) host mesh with sharded params and KV (DESIGN.md §4).
"""

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.core.kan_layer import resolve_inference_method
from repro.models import lm
from repro.serve.engine import Engine, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=("static", "continuous"),
                    default="static")
    ap.add_argument("--chunk-steps", type=int, default=4)
    ap.add_argument("--shared-prefix", action="store_true",
                    help="paged-KV demo: one shared system prompt + unique "
                         "suffixes, exercising prefix-cache hits end to end")
    ap.add_argument("--mesh", type=str, default=None, metavar="DxM",
                    help="serve on a (data, model) host mesh, e.g. 1x2 "
                         "(DESIGN.md §4); default: single device")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: drafts per verify window "
                         "(0 disables; outputs bit-identical either way — "
                         "DESIGN.md §9)")
    ap.add_argument("--draft-layers", type=int, default=1,
                    help="speculative: unit repeats kept in the drafter")
    args = ap.parse_args(argv)
    if args.shared_prefix and args.engine != "continuous":
        ap.error("--shared-prefix needs --engine continuous (paged KV)")
    if args.spec_k < 0:
        ap.error(f"--spec-k must be >= 0, got {args.spec_k}")
    if args.spec_k > 0 and args.engine != "continuous":
        ap.error("--spec-k needs --engine continuous")

    mesh = None
    if args.mesh is not None:
        from repro.launch.mesh import make_host_mesh, parse_mesh_shape

        try:
            mesh = make_host_mesh(parse_mesh_shape(args.mesh))
        except ValueError as e:
            ap.error(str(e))
        print(f"mesh={dict(mesh.shape)} over {mesh.size} host devices "
              f"(params + KV sharded; same outputs as single-device)")
    arch = configs.get_reduced("kanformer-100m")
    params = lm.init_params(jax.random.PRNGKey(0), arch.model)
    eng = Engine(params, arch.model,
                 ServeConfig(max_seq=96, max_new_tokens=16,
                             paged=args.shared_prefix, block_size=8,
                             mesh=mesh, spec_k=args.spec_k,
                             draft_layers=args.draft_layers))
    rs = np.random.RandomState(0)
    if args.shared_prefix:
        # system-prompt-heavy workload: 32 shared tokens, 3-8 unique ones
        system = rs.randint(0, arch.model.vocab, 32).astype(np.int32)
        requests = [
            np.concatenate([
                system,
                rs.randint(0, arch.model.vocab, rs.randint(3, 9)).astype(np.int32),
            ])
            for _ in range(12)
        ]
    else:
        requests = [
            rs.randint(0, arch.model.vocab, rs.randint(4, 24)).astype(np.int32)
            for _ in range(12)
        ]
    print(f"backend={jax.default_backend()} engine={args.engine} "
          f"kan_method_prefill={resolve_inference_method(rows=4 * 24)} "
          f"kan_method_decode={resolve_inference_method(rows=4)} "
          f"decode=scan (one compiled program per generation/chunk)")
    t0 = time.time()
    if args.engine == "continuous":
        outs = eng.serve_continuous(requests, slots=4,
                                    chunk_steps=args.chunk_steps)
    else:
        outs = eng.serve_requests(requests, batch_size=4)
    dt = time.time() - t0
    n_tok = sum(len(o) for o in outs)
    print(f"served {len(requests)} requests / {n_tok} new tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s, {jax.default_backend()})")
    if args.engine == "continuous" and eng.last_serve_stats:
        print(f"mean_slot_utilization="
              f"{eng.last_serve_stats['mean_slot_utilization']:.3f}")
        if args.shared_prefix:
            p = eng.last_serve_stats["paged"]
            total = p["prefill_tokens_computed"] + p["prefill_tokens_saved"]
            print(f"paged: prefix_hit_blocks={p['prefix_hit_blocks']} "
                  f"prefill_tokens_saved={p['prefill_tokens_saved']}/{total} "
                  f"blocks_watermark={p['blocks_in_use_watermark']}"
                  f"/{p['pool_blocks'] - 1}")
        if args.spec_k > 0:
            sp = eng.last_serve_stats["spec"]
            print(f"speculative: k={sp['spec_k']} "
                  f"draft_layers={sp['draft_layers']} "
                  f"acceptance_rate={sp['acceptance_rate']:.3f} "
                  f"windows={sp['windows']}")
    for i, o in enumerate(outs[:3]):
        print(f"  req{i} prompt_len={len(requests[i])} -> {o[:8].tolist()}...")


if __name__ == "__main__":
    main()
