"""Batched serving demo: serve a small kanformer with batched requests
through the prefill+decode engine.

    PYTHONPATH=src python examples/serve_kan.py                      # static
    PYTHONPATH=src python examples/serve_kan.py --engine continuous  # slots

``--engine static`` drains length-sorted fixed buckets;
``--engine continuous`` recycles batch slots the moment a request finishes
(EOS or budget) — the software analogue of the paper's never-idle PEs.
"""

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.core.kan_layer import resolve_inference_method
from repro.models import lm
from repro.serve.engine import Engine, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=("static", "continuous"),
                    default="static")
    ap.add_argument("--chunk-steps", type=int, default=4)
    args = ap.parse_args(argv)

    arch = configs.get_reduced("kanformer-100m")
    params = lm.init_params(jax.random.PRNGKey(0), arch.model)
    eng = Engine(params, arch.model, ServeConfig(max_seq=96, max_new_tokens=16))
    rs = np.random.RandomState(0)
    requests = [
        rs.randint(0, arch.model.vocab, rs.randint(4, 24)).astype(np.int32)
        for _ in range(12)
    ]
    print(f"backend={jax.default_backend()} engine={args.engine} "
          f"kan_method_prefill={resolve_inference_method(rows=4 * 24)} "
          f"kan_method_decode={resolve_inference_method(rows=4)} "
          f"decode=scan (one compiled program per generation/chunk)")
    t0 = time.time()
    if args.engine == "continuous":
        outs = eng.serve_continuous(requests, slots=4,
                                    chunk_steps=args.chunk_steps)
    else:
        outs = eng.serve_requests(requests, batch_size=4)
    dt = time.time() - t0
    n_tok = sum(len(o) for o in outs)
    print(f"served {len(requests)} requests / {n_tok} new tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s, {jax.default_backend()})")
    if args.engine == "continuous" and eng.last_serve_stats:
        print(f"mean_slot_utilization="
              f"{eng.last_serve_stats['mean_slot_utilization']:.3f}")
    for i, o in enumerate(outs[:3]):
        print(f"  req{i} prompt_len={len(requests[i])} -> {o[:8].tolist()}...")


if __name__ == "__main__":
    main()
