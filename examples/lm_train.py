"""End-to-end LM training driver (brief deliverable (b)): train the
kanformer (the paper's technique as the FFN of a decoder LM) on the
deterministic synthetic LM stream, with checkpoint/resume.

The full kanformer-100m config is CPU-prohibitive for hundreds of steps, so
the default here is the reduced config (same code path as the full one —
select it with --full on real hardware). A few hundred steps reach a clearly
decreasing loss; the run double-checks resume-from-checkpoint equivalence.

    PYTHONPATH=src python examples/lm_train.py [--steps 300] [--full]
"""

import argparse
import shutil
import tempfile

from repro.launch import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    ckpt = tempfile.mkdtemp(prefix="kanformer_ckpt_")
    argv = [
        "--arch", "kanformer-100m",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "64", "--lr", "2e-3",
        "--ckpt-dir", ckpt, "--ckpt-every", str(max(50, args.steps // 4)),
        "--log-every", "20",
    ]
    if not args.full:
        argv.append("--reduced")
    rc = T.main(argv)
    # demonstrate restart-from-checkpoint: run 20 more steps resuming
    print("\n[restart drill] resuming from latest checkpoint ...")
    rc2 = T.main(argv[:3] + [str(args.steps + 20)] + argv[4:])
    shutil.rmtree(ckpt, ignore_errors=True)
    return rc or rc2


if __name__ == "__main__":
    raise SystemExit(main())
