"""MNIST-KAN [784, 64, 10] (paper Table II, G=10, P=3): train fp32, then run
the integer-only KAN-SAs datapath and report the accuracy drop (paper §V:
96.58% -> 96.0%, <1% drop).

Offline container: MNIST is a synthetic class-conditional stand-in
(data/pipeline.mnist_like) — the claim under test is the fp32->int8 GAP.

    PYTHONPATH=src python examples/mnist_kan.py [--steps 400]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import quant_accuracy as qa


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    args = ap.parse_args()
    cfg, params, (Xte, Yte) = qa.train_mnist_kan(steps=args.steps)
    acc_fp = qa.accuracy_fp(cfg, params, Xte, Yte)
    acc_q = qa.accuracy_int8(cfg, params, Xte, Yte)
    print(f"MNIST-KAN [784,64,10] G=10 P=3 (synthetic MNIST stand-in)")
    print(f"  fp32 accuracy : {acc_fp*100:.2f}%   (paper, real MNIST: 96.58%)")
    print(f"  int8 accuracy : {acc_q*100:.2f}%   (paper, real MNIST: 96.0%)")
    print(f"  drop          : {(acc_fp-acc_q)*100:.2f} pts  (paper claim: <1 pt)")


if __name__ == "__main__":
    main()
