"""Quickstart: fit a KAN to a symbolic function and run every KAN-SAs
datapath on it (paper §II-A + §III).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kan_layer as kl
from repro.core import quantization as q
from repro.data import pipeline as dp


def main():
    # f(x, y) = exp(sin(pi x) + y^2), the KAN paper's flavour of target
    X, Y = dp.regression_set(2048, seed=0)
    Xte, Yte = dp.regression_set(512, seed=1)
    cfg = kl.KANNetConfig(layers=(2, 8, 1), G=5, P=3)
    params = kl.init_kan_net(jax.random.PRNGKey(0), cfg)

    def loss_fn(p):
        pred = kl.kan_net_apply(p, jnp.asarray(X), cfg)
        return jnp.mean((pred - jnp.asarray(Y)) ** 2)

    gfn = jax.jit(jax.value_and_grad(loss_fn))
    lr = 0.02
    for i in range(300):
        l, g = gfn(params)
        params = jax.tree.map(lambda p_, g_: p_ - lr * g_, params, g)
        if i % 50 == 0:
            print(f"step {i:4d} train mse {float(l):.5f}")

    def test_mse(method):
        pred = kl.kan_net_apply(params, jnp.asarray(Xte), cfg, method=method)
        return float(jnp.mean((pred - jnp.asarray(Yte)) ** 2))

    print("\nKAN-SAs datapaths on the trained model (test MSE):")
    for method in ("dense", "compact", "lut", "fused"):
        print(f"  {method:8s} {test_mse(method):.5f}")

    # integer-only inference (paper §V)
    g0 = cfg.grid()
    h = jnp.asarray(Xte)
    for i, p in enumerate(params):
        if i > 0:
            h = jnp.tanh(h)
        h = q.quantized_kan_forward(q.quantize_kan_layer(p, g0), h)
    print(f"  int8     {float(jnp.mean((h - jnp.asarray(Yte))**2)):.5f}")


if __name__ == "__main__":
    main()
