"""Paper Table II: the collected KAN application workloads, executed through
the GEMM formulation end-to-end in JAX (dense vs fused-kernel paths), plus
their SA-model cycle counts. One row per application."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sa_model as sm
from repro.core.bspline import SplineGrid
from repro.core import kan_layer as kl


def _run_app_jax(layers, G, P, BS=32, method="dense"):
    cfg = kl.KANNetConfig(layers=tuple(layers), G=G, P=P)
    params = kl.init_kan_net(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(
        np.random.RandomState(0).uniform(-1, 1, (BS, layers[0])).astype(np.float32)
    )
    f = jax.jit(lambda p, x: kl.kan_net_apply(p, x, cfg, method=method))
    out = f(params, x)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(5):
        out = f(params, x)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / 5 * 1e6


APPS = {
    "5G-STARDUST": ([168, 40, 40, 40, 24], 5, 3),
    "Catch22-KAN": ([22, 10], 3, 3),
    "CF-KAN": ([2810, 512, 2810], 2, 3),
    "U-KAN": ([512, 1024, 512], 5, 3),
    "GKAN": ([200, 16, 7], 2, 1),
    "Prefetcher": ([5, 64, 128], 4, 3),
    "MNIST-KAN": ([784, 64, 10], 10, 3),
}


def run() -> list[tuple[str, float, str]]:
    rows = []
    apps_sa = sm.paper_workloads(64)
    for name, (layers, G, P) in APPS.items():
        us_dense = _run_app_jax(layers, G, P, method="dense")
        ws = apps_sa[name]
        M = max(w.M for w in ws)
        N = max(w.N for w in ws)
        conv = sm.run_suite(sm.SAConfig(32, 32, "scalar"), ws)
        kans = sm.run_suite(sm.SAConfig(16, 16, "nm", N=N, M=M), ws)
        rows.append(
            (
                f"tableII.{name}",
                us_dense,
                f"layers={layers};G={G};P={P};"
                f"sa_cycles_conv={conv.cycles:.3g};sa_cycles_kansas={kans.cycles:.3g};"
                f"cycle_cut={conv.cycles/kans.cycles:.2f}x",
            )
        )
    return rows
