"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
the dry-run artifacts.

    PYTHONPATH=src:. python -m benchmarks.report [--baseline artifacts/dryrun_baseline]
"""

import argparse
import glob
import json
import os

from benchmarks.roofline import HBM_BW, ICI_BW, PEAK_FLOPS


def load(art_dir, include_variants=False):
    cells = {}
    for p in glob.glob(os.path.join(art_dir, "*.json")):
        base = os.path.basename(p)
        is_variant = "__opt" in base
        if is_variant and not include_variants:
            continue
        d = json.load(open(p))
        is_cost = "__cost" in base
        cells[(d["arch"], d["shape"], d["mesh"], is_cost)] = d
    return cells


def fmt_b(x):
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if abs(x) >= div:
            return f"{x/div:.2f} {unit}"
    return f"{x:.0f} B"


def dryrun_table(cells) -> str:
    lines = [
        "| arch | shape | mesh | mode | params | peak/dev | args/dev | HLO GFLOPs/dev* | coll bytes/dev* | compile s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, mesh, is_cost), d in sorted(cells.items()):
        if is_cost:
            continue
        m = d["memory"]
        lines.append(
            f"| {arch} | {shape} | {mesh} | {d['mode']} | {d['n_params']/1e9:.2f}B "
            f"| {fmt_b(m['peak_bytes'])} | {fmt_b(m['argument_bytes'])} "
            f"| {d['cost']['flops']/1e9:.1f} | {fmt_b(d['collectives'].get('total',0))} "
            f"| {d.get('compile_s','-')} |"
        )
    lines.append("")
    lines.append(
        "\\* production (scan-over-layers) graph: XLA cost_analysis counts "
        "while-loop bodies once, so these two columns UNDERCOUNT the true "
        "per-step numbers — the §Roofline table uses the cost-faithful "
        "(`__cost`) compiles instead."
    )
    return "\n".join(lines)


def roofline_table(cells) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | roofline frac | useful frac (6ND/HLO) | peak GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, mesh, is_cost), d in sorted(cells.items()):
        if is_cost or mesh != "single":
            continue
        c = cells.get((arch, shape, "single", True))
        if c is None:
            continue
        n_dev = d["n_devices"]
        t_c = c["flops"] / PEAK_FLOPS
        t_m = c["bytes_accessed"] / HBM_BW
        t_x = c["collectives"].get("total", 0.0) / ICI_BW
        tmax = max(t_c, t_m, t_x)
        dom = {t_c: "compute", t_m: "memory", t_x: "collective"}[tmax]
        mf = c.get("model_flops_global", 0.0) / n_dev
        frac = (mf / c["flops"]) if c["flops"] else 0
        lines.append(
            f"| {arch} | {shape} | {t_c:.3e} | {t_m:.3e} | {t_x:.3e} | {dom} "
            f"| {t_c/tmax:.2f} | {frac:.2f} | {d['memory']['peak_bytes']/1e9:.2f} |"
        )
    return "\n".join(lines)


def perf_compare(cells, base_cells) -> str:
    lines = [
        "| arch | shape | metric | baseline | optimized | delta |",
        "|---|---|---|---|---|---|",
    ]
    for key in sorted(set(cells) & set(base_cells)):
        arch, shape, mesh, is_cost = key
        if is_cost or mesh != "single":
            continue
        a, b = base_cells[key], cells[key]
        if a["mode"] not in ("train", "decode"):
            continue
        pk_a, pk_b = a["memory"]["peak_bytes"], b["memory"]["peak_bytes"]
        if abs(pk_a - pk_b) / max(pk_a, 1) > 0.02:
            lines.append(
                f"| {arch} | {shape} | peak mem/dev | {fmt_b(pk_a)} | {fmt_b(pk_b)} "
                f"| {(pk_b-pk_a)/pk_a*100:+.1f}% |"
            )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default="artifacts/dryrun")
    ap.add_argument("--baseline", default="artifacts/dryrun_baseline")
    args = ap.parse_args()
    cells = load(args.art)
    print("## §Dry-run\n")
    print(dryrun_table(cells))
    print("\n## §Roofline (single pod, 256 chips, v5e constants)\n")
    print(roofline_table(cells))
    if os.path.isdir(args.baseline):
        print("\n## §Perf memory before/after\n")
        print(perf_compare(cells, load(args.baseline)))


if __name__ == "__main__":
    main()
