"""KAN GEMM datapaths (paper §III-A, §IV-A): dense-B baseline vs compact-N:M
vs tabulated vs the fused Pallas kernel vs the sparse N:M kernel, with the
HBM-byte accounting that motivates both kernel designs on TPU:

* **fused** (large batch): B never hits HBM — traffic X+C+Wb+Y instead of
  X+B+C+Wb+Y, a (G+P)x cut of the activation stream (DESIGN.md §2);
* **sparse** (decode/small batch): only the P+1-row coefficient slabs live
  inputs touch are fetched — a (G+P)/(P+1)x cut of the *coefficient*
  stream, which dominates when BS is small (DESIGN.md §2a).

On CPU the kernels run in interpret mode, so their µs numbers measure the
interpreter, not the hardware; the compiled-path costs are *modeled* via
the HBM-traffic formulas.  The module also:

* consults/records the tile autotuner (``repro.kernels.autotune``) per
  kernel (the sparse kernels have their own candidate space) and reports
  the chosen tiles;
* measures fused vs sparse at decode shapes (BS <= 8) — the regime the
  sparse kernel exists for;
* counts ``pallas_call`` ops in each kernel layer's jaxpr — proving spline
  + base term are ONE kernel launch for both datapaths;
* exposes :func:`report` — the dict ``benchmarks/run.py`` writes to
  ``BENCH_kan_paths.json`` so future PRs have a perf trajectory.

``$KAN_SAS_BENCH_SMOKE=1`` shrinks the main shape and iteration counts for
CI smoke runs (the report keys and sparse-path rows stay identical).
"""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kan_layer as kl
from repro.core.bspline import SplineGrid, build_lut
from repro.kernels import autotune as tune
from repro.kernels import ops as kops


def _smoke() -> bool:
    return os.environ.get("KAN_SAS_BENCH_SMOKE", "") not in ("", "0")


def _main_shape():
    return (256, 64, 128) if _smoke() else (2048, 256, 256)  # (BS, K, N)


DECODE_BATCHES = (1, 8)          # the decode shapes the sparse kernel targets
DECODE_KN = (256, 256)           # decode layer dims — always the full config
                                 # (BS <= 8 keeps this cheap even in smoke;
                                 # at toy K the whole layer fits one grid
                                 # step and the comparison degenerates)
PROBE = (256, 64, 128)           # autotune probe shape (interpret mode is slow)


def _bench(f, *args, iters=3):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _bench_interleaved(fns: dict, iters=3, repeats=5) -> dict:
    """Best-of-repeats, *interleaved* across the contenders: system noise
    (this is a shared CI/CPU box) drifts on the seconds scale, so timing A
    fully before B biases whichever ran during the quiet window.  Round-
    robin repeats + min estimate the kernels' intrinsic cost — noise on a
    loaded box is strictly additive, so min is the robust estimator for a
    comparative headline number."""
    samples = {name: [] for name in fns}
    for name, f in fns.items():
        jax.block_until_ready(f())          # warmup/compile outside timing
    for _ in range(repeats):
        for name, f in fns.items():
            samples[name].append(_bench(f, iters=iters))
    return {name: float(np.min(v)) for name, v in samples.items()}


def coeff_traffic_model(K, N, grid: SplineGrid, path: str, dtype_bytes=4):
    """Modeled coefficient-stream HBM bytes per layer call.

    The dense-band paths (dense/lut/fused) stream the full ``(K, M, N)``
    panel; the sparse N:M path fetches only the ``(P+1)``-row slabs live
    inputs touch — exact at BS=1 decode, and the working sets of a small
    decode batch overlap (DESIGN.md §2a for the accounting and caveats).
    """
    rows = grid.n_nonzero if path == "sparse" else grid.n_basis
    return K * rows * N * dtype_bytes


def traffic_model(BS, K, N, grid: SplineGrid, path: str, dtype_bytes=4):
    """Modeled total HBM bytes per layer call (DESIGN.md §2, §2a).

    ``fused`` reads x + coeff + base_w and writes y — the B panel and the
    base-GEMM's second x read never exist.  ``sparse`` additionally shrinks
    the coefficient read to the gathered slabs.  The unfused paths add the
    dense B panel (dense/lut) or the gathered coefficient slabs (compact),
    plus a separate base GEMM's x re-read."""
    M = grid.n_basis
    x = BS * K
    b = BS * K * M
    slabs = BS * K * grid.n_nonzero * N
    c = K * M * N
    wb = K * N
    y = BS * N
    if path == "fused":
        total = x + c + wb + y
    elif path == "sparse":
        total = x + coeff_traffic_model(K, N, grid, "sparse", 1) + wb + y
    elif path == "compact":
        total = x + slabs + y + x + wb + y
    else:  # dense / lut: materialised B panel + separate base GEMM
        total = x + b + c + y + x + wb + y
    return total * dtype_bytes


def _count_kernel_launches(fn, *args) -> int:
    """pallas_call ops in the jaxpr — the one-kernel-per-layer proof."""
    return str(jax.make_jaxpr(fn)(*args)).count("pallas_call")


def _build(g, BS_, K_, N_):
    rs = np.random.RandomState(0)
    x = jnp.asarray(rs.uniform(-1, 1, (BS_, K_)).astype(np.float32))
    params = kl.init_kan_layer(
        jax.random.PRNGKey(0), kl.KANLayerConfig(K_, N_, g)
    )
    return params, x


def _autotune_probe(g) -> dict:
    """Run the autotuner on the probe shape and return its report row."""
    pb, pk, pn = PROBE
    params, x = _build(g, pb, pk, pn)
    cands = [(32, 64, 4), (32, 128, 8), (64, 64, 8), (64, 128, 16),
             (128, 128, 8), (128, 128, 16)]
    return tune.autotune(
        "fused",
        lambda bb, bn, bk: kops.kan_fused_gemm(
            x, params["coeff"], g, base_w=params["base_w"],
            bb=bb, bn=bn, bk=bk,
        ),
        pb, pk, pn, g.n_basis, dtype=x.dtype, iters=1, candidates=cands,
    )


def _decode_report(g, K, N) -> dict:
    """Fused vs sparse at decode shapes (BS <= 8): autotune each kernel in
    its own candidate space, then measure with the winners — the crossover
    evidence for `resolve_inference_method` (DESIGN.md §2a)."""
    params, _ = _build(g, 8, K, N)
    iters = 2 if _smoke() else 5
    # Curated per-kernel candidates (interpret-mode compiles are the cost
    # here, not the timing): each kernel's decode-regime sweet spots from
    # its own candidate space — sparse's bk extends (G+P)/(P+1)x further
    # under the shared contraction-width budget (autotune.candidate_tiles).
    cands = {
        "fused": [(8, 128, 32), (8, 256, 64), (8, 256, 128)],
        "sparse": [(8, 128, 64), (8, 256, 128), (8, 256, 256)],
    }
    out: dict = {
        "shapes": [{"BS": bs, "K": K, "N": N} for bs in DECODE_BATCHES],
        "sparse_coeff_cut_vs_fused": round(
            coeff_traffic_model(K, N, g, "fused")
            / coeff_traffic_model(K, N, g, "sparse"), 2
        ),
        "rows": {},
    }
    for BS in DECODE_BATCHES:
        _, x = _build(g, BS, K, N)
        runs = {
            "fused": lambda bb, bn, bk: kops.kan_fused_gemm(
                x, params["coeff"], g, base_w=params["base_w"],
                bb=bb, bn=bn, bk=bk,
            ),
            "sparse": lambda bb, bn, bk: kops.kan_sparse_gemm(
                x, params["coeff"], g, base_w=params["base_w"],
                bb=bb, bn=bn, bk=bk,
            ),
        }
        row: dict = {}
        # One interleaved best-of-repeats pass over EVERY (kernel, tiles)
        # candidate: winner selection and the headline µs come from the same
        # noise-robust measurement (a separate one-shot autotune pass can
        # crown a bad tile on a loaded box and then faithfully re-time it).
        fns = {}
        for kernel, run in runs.items():
            for bb, bn, bk in cands[kernel]:
                t = (bb, min(bn, N), min(bk, K))
                fns[(kernel, t)] = (lambda run=run, t=t: run(*t))
        mins = _bench_interleaved(fns, iters=iters,
                                  repeats=5 if _smoke() else 9)
        for kernel in runs:
            best_t, best_us = min(
                ((t, mins[(k, t)]) for (k, t) in mins if k == kernel),
                key=lambda kv: kv[1],
            )
            tune.record_winner(kernel, BS, K, N, g.n_basis, x.dtype,
                               jax.default_backend(), best_t, best_us)
            path = "sparse" if kernel == "sparse" else "fused"
            row[kernel] = {
                "us_per_call": round(best_us, 1),
                "tiles": list(best_t),
                "hbm_model_bytes": traffic_model(BS, K, N, g, path),
                "coeff_model_bytes": coeff_traffic_model(K, N, g, path),
            }
        row["sparse_speedup_vs_fused"] = round(
            row["fused"]["us_per_call"] / max(row["sparse"]["us_per_call"], 1e-9),
            2,
        )
        out["rows"][f"BS={BS}"] = row
    return out


def report() -> dict:
    g = SplineGrid(-1.0, 1.0, 5, 3)
    BS, K, N = _main_shape()
    params, x = _build(g, BS, K, N)
    lut = jnp.asarray(build_lut(3, 256))
    at = _autotune_probe(g)
    # Tiles the MAIN-shape fused run actually uses (cache -> defaults ->
    # heuristic); pinned explicitly so the report and the measurement agree.
    main_tiles = tune.get_tiles("fused", BS, K, N, g.n_basis, x.dtype)

    def fused_fn(p, x):
        bb, bn, bk = main_tiles
        return kops.kan_fused_gemm(
            x, p["coeff"], g, base_w=p.get("base_w"), bb=bb, bn=bn, bk=bk
        )

    fns = {
        "dense": jax.jit(lambda p, x: kl.kan_layer_apply(p, x, g, "dense")),
        "compact": jax.jit(lambda p, x: kl.kan_layer_apply(p, x, g, "compact")),
        "lut": jax.jit(lambda p, x: kl.kan_layer_apply(p, x, g, "lut", lut=lut)),
        "fused_kernel": jax.jit(fused_fn),
    }
    backend = jax.default_backend()
    out: dict = {
        "shape": {"BS": BS, "K": K, "N": N, "G": g.G, "P": g.P},
        "backend": backend,
        "smoke": _smoke(),
        "note": "kernel µs are interpret-mode on non-TPU backends; "
                "hbm_model_bytes models the compiled (interpret=False) path",
        "autotune": {
            "probe_key": at["key"],
            "probe_tiles": list(at["tiles"]),
            "probe_us": None if at["us"] != at["us"] else round(at["us"], 1),
            "probe_candidates_us": at["candidates"],
            "main_tiles": list(main_tiles),
        },
        "fused_kernel_launches_per_layer": _count_kernel_launches(
            lambda: kl.kan_layer_apply(params, x, g, "fused")
        ),
        "sparse_kernel_launches_per_layer": _count_kernel_launches(
            lambda: kl.kan_layer_apply(params, x[:8], g, "sparse")
        ),
        "paths": {},
    }
    ref = None
    for name, f in fns.items():
        us = _bench(f, params, x)
        y = f(params, x)
        if ref is None:
            ref = y
        err = float(jnp.abs(y - ref).max() / (jnp.abs(ref).max() + 1e-9))
        path_kind = "fused" if name == "fused_kernel" else (
            "compact" if name == "compact" else "dense"
        )
        out["paths"][name] = {
            "us_per_call": round(us, 1),
            "rel_err_vs_dense": err,
            "hbm_model_bytes": traffic_model(BS, K, N, g, path_kind),
            "coeff_model_bytes": coeff_traffic_model(K, N, g, path_kind),
        }
    # The sparse path at its design shape (decode, full layer dims):
    # measured against fused on the same shapes, each with its own
    # autotuned tiles.
    Kd, Nd = DECODE_KN
    out["decode"] = _decode_report(g, Kd, Nd)
    # Sparse correctness + accounting row (the main shape is the fused
    # kernel's regime; running sparse there would only time the interpreter
    # doing the wrong thing slowly — µs and bytes below are the decode
    # design shape's, rel_err is checked on the main-shape slice).
    ys = kl.kan_layer_apply(params, x[:8], g, "sparse")
    yr = kl.kan_layer_apply(params, x[:8], g, "dense")
    out["paths"]["sparse_kernel"] = {
        "us_per_call": out["decode"]["rows"]["BS=8"]["sparse"]["us_per_call"],
        "rel_err_vs_dense": float(
            jnp.abs(ys - yr).max() / (jnp.abs(yr).max() + 1e-9)
        ),
        "hbm_model_bytes": traffic_model(8, Kd, Nd, g, "sparse"),
        "coeff_model_bytes": coeff_traffic_model(Kd, Nd, g, "sparse"),
        "note": f"measured at its decode design shape (BS=8, K={Kd}, "
                f"N={Nd}), see 'decode'",
    }
    out["fused_hbm_cut_vs_dense"] = round(
        traffic_model(BS, K, N, g, "dense") / traffic_model(BS, K, N, g, "fused"),
        2,
    )
    out["sparse_coeff_cut_vs_fused"] = round(
        coeff_traffic_model(K, N, g, "fused")
        / coeff_traffic_model(K, N, g, "sparse"), 2
    )
    return out


def run() -> list[tuple[str, float, str]]:
    rep = report()
    rows = []
    for name, row in rep["paths"].items():
        rows.append(
            (
                f"kanpaths.{name}",
                row["us_per_call"],
                f"rel_err={row['rel_err_vs_dense']:.1e};"
                f"hbm_model_bytes={row['hbm_model_bytes']:.3g};"
                f"note={'interpret-mode (CPU); TPU is the target' if name.endswith('_kernel') and rep['backend'] != 'tpu' else 'XLA'}",
            )
        )
    for bs_key, drow in rep["decode"]["rows"].items():
        rows.append(
            (
                f"kanpaths.decode.{bs_key}",
                drow["sparse"]["us_per_call"],
                f"fused_us={drow['fused']['us_per_call']};"
                f"sparse_speedup={drow['sparse_speedup_vs_fused']}x;"
                f"coeff_cut={rep['decode']['sparse_coeff_cut_vs_fused']}x",
            )
        )
    rows.append(
        ("kanpaths.fused_hbm_cut", 0.0,
         f"traffic_cut={rep['fused_hbm_cut_vs_dense']:.2f}x")
    )
    rows.append(
        ("kanpaths.sparse_coeff_cut", 0.0,
         f"coeff_cut={rep['sparse_coeff_cut_vs_fused']:.2f}x")
    )
    rows.append(
        ("kanpaths.fused_kernel_launches", 0.0,
         f"pallas_calls_per_layer={rep['fused_kernel_launches_per_layer']};"
         f"tiles={'x'.join(map(str, rep['autotune']['main_tiles']))}")
    )
    rows.append(
        ("kanpaths.sparse_kernel_launches", 0.0,
         f"pallas_calls_per_layer={rep['sparse_kernel_launches_per_layer']}")
    )
    # stash for benchmarks/run.py to write BENCH_kan_paths.json
    run.last_report = rep  # type: ignore[attr-defined]
    return rows
