"""KAN GEMM datapaths (paper §III-A): dense-B baseline vs compact-N:M vs
tabulated vs the fused Pallas kernel, with the HBM-byte accounting that
motivates the fused design on TPU (B never hits HBM: traffic X+C+Y instead
of X+B+C+Y, a (G+P)x cut of the activation stream)."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kan_layer as kl
from repro.core.bspline import SplineGrid, build_lut


def _bench(f, *args, iters=10):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def traffic_model(BS, K, N, grid: SplineGrid, fused: bool, dtype_bytes=4):
    M = grid.n_basis
    x = BS * K
    b = BS * K * M
    c = K * M * N
    y = BS * N
    total = (x + c + y) if fused else (x + b + c + y)
    return total * dtype_bytes


def run() -> list[tuple[str, float, str]]:
    g = SplineGrid(-1.0, 1.0, 5, 3)
    BS, K, N = 2048, 256, 256
    rs = np.random.RandomState(0)
    x = jnp.asarray(rs.uniform(-1, 1, (BS, K)).astype(np.float32))
    cfg = kl.KANLayerConfig(K, N, g)
    params = kl.init_kan_layer(jax.random.PRNGKey(0), cfg)
    lut = jnp.asarray(build_lut(3, 256))

    fns = {
        "dense": jax.jit(lambda p, x: kl.kan_layer_apply(p, x, g, "dense")),
        "compact": jax.jit(lambda p, x: kl.kan_layer_apply(p, x, g, "compact")),
        "lut": jax.jit(lambda p, x: kl.kan_layer_apply(p, x, g, "lut", lut=lut)),
        "fused_kernel": jax.jit(
            lambda p, x: kl.kan_layer_apply(p, x, g, "fused")
        ),
    }
    rows = []
    ref = None
    for name, f in fns.items():
        us = _bench(f, params, x)
        out = f(params, x)
        if ref is None:
            ref = out
        err = float(jnp.abs(out - ref).max() / (jnp.abs(ref).max() + 1e-9))
        hbm = traffic_model(BS, K, N, g, fused=(name == "fused_kernel"))
        rows.append(
            (
                f"kanpaths.{name}",
                us,
                f"rel_err={err:.1e};hbm_model_bytes={hbm:.3g};"
                f"note={'interpret-mode (CPU); TPU is the target' if name=='fused_kernel' else 'XLA'}",
            )
        )
    cut = traffic_model(BS, K, N, g, False) / traffic_model(BS, K, N, g, True)
    rows.append(("kanpaths.fused_hbm_cut", 0.0, f"traffic_cut={cut:.2f}x"))
    return rows
