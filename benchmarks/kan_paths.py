"""KAN GEMM datapaths (paper §III-A): dense-B baseline vs compact-N:M vs
tabulated vs the fused Pallas kernel, with the HBM-byte accounting that
motivates the fused design on TPU (B never hits HBM: traffic X+C+Wb+Y
instead of X+B+C+Wb+Y, a (G+P)x cut of the activation stream — DESIGN.md §2).

On CPU the fused path runs in interpret mode, so its µs numbers measure the
interpreter, not the hardware; the compiled-path costs are *modeled* via the
HBM-traffic formula (interpret=False path modeled, interpret=True measured).
The module also:

* consults/records the tile autotuner (``repro.kernels.autotune``) on a
  reduced probe shape and reports the chosen tiles;
* counts ``pallas_call`` ops in the fused layer's jaxpr — proving the whole
  layer (spline + base term) is ONE kernel launch;
* exposes :func:`report` — the dict ``benchmarks/run.py`` writes to
  ``BENCH_kan_paths.json`` so future PRs have a perf trajectory.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kan_layer as kl
from repro.core.bspline import SplineGrid, build_lut
from repro.kernels import autotune as tune
from repro.kernels import ops as kops

BS, K, N = 2048, 256, 256
PROBE = (256, 64, 128)       # autotune probe shape (interpret mode is slow)


def _bench(f, *args, iters=3):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def traffic_model(BS, K, N, grid: SplineGrid, path: str, dtype_bytes=4):
    """Modeled HBM bytes per layer call (DESIGN.md §2).

    ``fused`` reads x + coeff + base_w and writes y — the B panel and the
    base-GEMM's second x read never exist.  The unfused paths add the dense
    B panel (dense/lut) or the gathered coefficient slabs (compact), plus a
    separate base GEMM's x re-read."""
    M = grid.n_basis
    x = BS * K
    b = BS * K * M
    slabs = BS * K * grid.n_nonzero * N
    c = K * M * N
    wb = K * N
    y = BS * N
    if path == "fused":
        total = x + c + wb + y
    elif path == "compact":
        total = x + slabs + y + x + wb + y
    else:  # dense / lut: materialised B panel + separate base GEMM
        total = x + b + c + y + x + wb + y
    return total * dtype_bytes


def _count_kernel_launches(fn, *args) -> int:
    """pallas_call ops in the jaxpr — the one-kernel-per-layer proof."""
    return str(jax.make_jaxpr(fn)(*args)).count("pallas_call")


def _build(g, BS_, K_, N_):
    rs = np.random.RandomState(0)
    x = jnp.asarray(rs.uniform(-1, 1, (BS_, K_)).astype(np.float32))
    params = kl.init_kan_layer(
        jax.random.PRNGKey(0), kl.KANLayerConfig(K_, N_, g)
    )
    return params, x


def _autotune_probe(g) -> dict:
    """Run the autotuner on the probe shape and return its report row."""
    pb, pk, pn = PROBE
    params, x = _build(g, pb, pk, pn)
    cands = [(32, 64, 4), (32, 128, 8), (64, 64, 8), (64, 128, 16),
             (128, 128, 8), (128, 128, 16)]
    return tune.autotune(
        "fused",
        lambda bb, bn, bk: kops.kan_fused_gemm(
            x, params["coeff"], g, base_w=params["base_w"],
            bb=bb, bn=bn, bk=bk,
        ),
        pb, pk, pn, g.n_basis, dtype=x.dtype, iters=1, candidates=cands,
    )


def report() -> dict:
    g = SplineGrid(-1.0, 1.0, 5, 3)
    params, x = _build(g, BS, K, N)
    lut = jnp.asarray(build_lut(3, 256))
    at = _autotune_probe(g)
    # Tiles the MAIN-shape fused run actually uses (cache -> defaults ->
    # heuristic); pinned explicitly so the report and the measurement agree.
    main_tiles = tune.get_tiles("fused", BS, K, N, g.n_basis, x.dtype)

    def fused_fn(p, x):
        bb, bn, bk = main_tiles
        return kops.kan_fused_gemm(
            x, p["coeff"], g, base_w=p.get("base_w"), bb=bb, bn=bn, bk=bk
        )

    fns = {
        "dense": jax.jit(lambda p, x: kl.kan_layer_apply(p, x, g, "dense")),
        "compact": jax.jit(lambda p, x: kl.kan_layer_apply(p, x, g, "compact")),
        "lut": jax.jit(lambda p, x: kl.kan_layer_apply(p, x, g, "lut", lut=lut)),
        "fused_kernel": jax.jit(fused_fn),
    }
    backend = jax.default_backend()
    out: dict = {
        "shape": {"BS": BS, "K": K, "N": N, "G": g.G, "P": g.P},
        "backend": backend,
        "note": "fused µs are interpret-mode on non-TPU backends; "
                "hbm_model_bytes models the compiled (interpret=False) path",
        "autotune": {
            "probe_key": at["key"],
            "probe_tiles": list(at["tiles"]),
            "probe_us": None if at["us"] != at["us"] else round(at["us"], 1),
            "probe_candidates_us": at["candidates"],
            "main_tiles": list(main_tiles),
        },
        "fused_kernel_launches_per_layer": _count_kernel_launches(
            lambda: kl.kan_layer_apply(params, x, g, "fused")
        ),
        "paths": {},
    }
    ref = None
    for name, f in fns.items():
        us = _bench(f, params, x)
        y = f(params, x)
        if ref is None:
            ref = y
        err = float(jnp.abs(y - ref).max() / (jnp.abs(ref).max() + 1e-9))
        path_kind = "fused" if name == "fused_kernel" else (
            "compact" if name == "compact" else "dense"
        )
        out["paths"][name] = {
            "us_per_call": round(us, 1),
            "rel_err_vs_dense": err,
            "hbm_model_bytes": traffic_model(BS, K, N, g, path_kind),
        }
    out["fused_hbm_cut_vs_dense"] = round(
        traffic_model(BS, K, N, g, "dense") / traffic_model(BS, K, N, g, "fused"),
        2,
    )
    return out


def run() -> list[tuple[str, float, str]]:
    rep = report()
    rows = []
    for name, row in rep["paths"].items():
        rows.append(
            (
                f"kanpaths.{name}",
                row["us_per_call"],
                f"rel_err={row['rel_err_vs_dense']:.1e};"
                f"hbm_model_bytes={row['hbm_model_bytes']:.3g};"
                f"note={'interpret-mode (CPU); TPU is the target' if name == 'fused_kernel' and rep['backend'] != 'tpu' else 'XLA'}",
            )
        )
    rows.append(
        ("kanpaths.fused_hbm_cut", 0.0,
         f"traffic_cut={rep['fused_hbm_cut_vs_dense']:.2f}x")
    )
    rows.append(
        ("kanpaths.fused_kernel_launches", 0.0,
         f"pallas_calls_per_layer={rep['fused_kernel_launches_per_layer']};"
         f"tiles={'x'.join(map(str, rep['autotune']['main_tiles']))}")
    )
    # stash for benchmarks/run.py to write BENCH_kan_paths.json
    run.last_report = rep  # type: ignore[attr-defined]
    return rows
