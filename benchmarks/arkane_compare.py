"""Paper §V-B: B-spline evaluation vs ArKANe [13].

(a) The paper's iso-area arithmetic: (P+1) FPMax FMA tiles (4 x 0.0081 mm^2)
    fit 72 tabulated B-spline units (450 um^2) -> >=72x throughput at high M.
(b) A software measurement of the same contrast on this host: tabulated LUT
    evaluation vs recursive Cox-de Boor in JAX (wall-clock, jitted)."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bspline as bs
from repro.core import sa_model as sm


def _time(f, *args, iters=20):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> list[tuple[str, float, str]]:
    rows = []
    # (a) paper arithmetic
    units = sm.arkane_equiv_units(3)
    n_in = 100_000
    arkane_c = sm.arkane_cycles(n_in, G=5, P=3)
    ours_c = sm.kansas_bspline_cycles(n_in, units)
    rows.append(
        (
            "arkane.iso_area_speedup",
            0.0,
            f"units={units}(paper=72);speedup={arkane_c/ours_c:.1f}x;paper>=72x",
        )
    )
    # (b) software contrast on this host
    g = bs.SplineGrid(-1.0, 1.0, 5, 3)
    x = jnp.asarray(np.random.RandomState(0).uniform(-1, 1, (65536,)).astype(np.float32))
    lut = jnp.asarray(bs.build_lut(3, 256))
    f_rec = jax.jit(lambda x: bs.cox_de_boor_dense(x, g))
    f_lut = jax.jit(lambda x: bs.lut_basis_compact(x, g, lut)[0])
    us_rec = _time(f_rec, x)
    us_lut = _time(f_lut, x)
    rows.append(
        (
            "arkane.software_lut_vs_recursive",
            us_lut,
            f"recursive_us={us_rec:.0f};lut_us={us_lut:.0f};"
            f"speedup={us_rec/us_lut:.1f}x(host CPU, 64k inputs)",
        )
    )
    return rows
