"""Roofline table (brief deliverable (g)) from the dry-run artifacts.

Per (arch x shape), single-pod mesh (256 chips), TPU v5e constants:
    compute   = HLO_FLOPs_per_device / 197e12
    memory    = HLO_bytes_per_device / 819e9
    collective= collective_bytes_per_device / 50e9   (per-link ICI)

FLOPs/bytes come from the cost-faithful compiles (__cost.json: loop-free
graphs, R'=1,2 extrapolation — see launch/dryrun.py); collective bytes from
the same. memory_analysis (fit proof) comes from the production compile.
"""

import glob
import json
import os

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link

ART = os.path.join(os.getcwd(), "artifacts", "dryrun")


def load_cells():
    cells = {}
    for p in glob.glob(os.path.join(ART, "*.json")):
        base = os.path.basename(p)
        if "__opt" in base:
            continue  # hillclimb variants live in EXPERIMENTS.md SecPerf
        with open(p) as f:
            d = json.load(f)
        key = (d["arch"], d["shape"], d["mesh"], "__cost" in base)
        cells[key] = d
    return cells


def analytic_memory_s(arch: str, shape: str, n_dev: int) -> float | None:
    """Fusion-aware analytic HBM lower bound (models/costs.py): XLA's
    'bytes accessed' is pre-fusion and so an upper bound; the truth on a
    real TPU sits between the two (EXPERIMENTS.md §Roofline)."""
    try:
        from repro import configs as _cfg
        from repro.configs.common import SHAPES
        from repro.models import costs as _costs

        model = _cfg.get_config(arch).model
        cell = SHAPES[shape]
        b = _costs.analytic_hbm_bytes(
            model, global_batch=cell.global_batch, seq=cell.seq_len,
            mode=cell.mode, n_devices=n_dev,
        )
        return b / HBM_BW
    except Exception:
        return None


def roofline_row(prod: dict, cost: dict | None) -> dict:
    n_dev = prod["n_devices"]
    flops = cost["flops"] if cost else prod["cost"]["flops"]
    bytes_ = cost["bytes_accessed"] if cost else prod["cost"]["bytes_accessed"]
    coll = (cost or prod)["collectives"].get("total", 0.0)
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    t_m_lo = analytic_memory_s(prod["arch"], prod["shape"], n_dev)
    t_x = coll / ICI_BW
    # bottleneck call uses the geometric mean of the memory bounds when the
    # analytic bound is available (upper bound alone overclassifies memory)
    t_m_mid = (t_m * t_m_lo) ** 0.5 if t_m_lo else t_m
    dom = max((t_c, "compute"), (t_m_mid, "memory"), (t_x, "collective"))
    mf = cost.get("model_flops_global", 0.0) / n_dev if cost else 0.0
    return {
        "compute_s": t_c,
        "memory_s": t_m,
        "memory_s_lo": t_m_lo,
        "collective_s": t_x,
        "dominant": dom[1],
        "model_flops_frac": (mf / flops) if flops and mf else None,
        "peak_gb": prod["memory"]["peak_bytes"] / 1e9,
        "roofline_frac": t_c / max(t_c, t_m_mid, t_x)
        if max(t_c, t_m_mid, t_x) > 0 else 0.0,
    }


def run() -> list[tuple[str, float, str]]:
    cells = load_cells()
    rows = []
    seen = sorted({(a, s) for (a, s, m, c) in cells if m == "single" and not c})
    for arch, shape in seen:
        prod = cells.get((arch, shape, "single", False))
        cost = cells.get((arch, shape, "single", True))
        if prod is None:
            continue
        r = roofline_row(prod, cost)
        mf = f"{r['model_flops_frac']:.2f}" if r["model_flops_frac"] else "-"
        mlo = f"{r['memory_s_lo']:.3e}" if r["memory_s_lo"] else "-"
        rows.append(
            (
                f"roofline.{arch}.{shape}",
                0.0,
                f"compute_s={r['compute_s']:.3e};memory_s_hi={r['memory_s']:.3e};"
                f"memory_s_lo={mlo};"
                f"collective_s={r['collective_s']:.3e};dominant={r['dominant']};"
                f"useful_frac={mf};peak_gb={r['peak_gb']:.2f};"
                f"roofline_frac={r['roofline_frac']:.3f}",
            )
        )
    n_multi = len([1 for (a, s, m, c) in cells if m == "multi" and not c])
    n_single = len([1 for (a, s, m, c) in cells if m == "single" and not c])
    rows.append(
        ("roofline.coverage", 0.0,
         f"single_pod_cells={n_single};multi_pod_cells={n_multi}")
    )
    return rows
