"""Paper Fig 7a/7b: average PE utilization and runtime (cycles) vs
post-synthesis area, conventional SA vs KAN-SAs, sweeping array sizes.

Setup per the paper: int8/int32 PEs, G=5, P=3 fixed (-> 4:8 N:M PEs),
averaged over all Table-II workloads except MNIST-KAN (G=10)."""

import time

from repro.core import sa_model as sm

SIZES = [(2, 2), (4, 4), (8, 8), (16, 16), (32, 32), (64, 64)]


def run() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    apps = sm.paper_workloads(64, fixed_gp=(5, 3))
    wls = [w for name, ws in apps.items() if name != "MNIST-KAN" for w in ws]
    rows = []
    for R, C in SIZES:
        conv = sm.run_suite(sm.SAConfig(R, C, "scalar"), wls)
        kans = sm.run_suite(sm.SAConfig(R, C, "nm", N=4, M=8), wls)
        a_c = sm.SAConfig(R, C, "scalar").area_mm2()
        a_k = sm.SAConfig(R, C, "nm", N=4, M=8).area_mm2()
        rows.append(
            (
                f"fig7.{R}x{C}",
                0.0,
                f"conv_util={conv.utilization*100:.1f}%;conv_area={a_c:.3f}mm2;"
                f"conv_cycles={conv.cycles:.3g};"
                f"kansas_util={kans.utilization*100:.1f}%;kansas_area={a_k:.3f}mm2;"
                f"kansas_cycles={kans.cycles:.3g}",
            )
        )
    # headline: iso-area runtime ratio (16x16 KAN-SAs vs 32x32 scalar)
    conv = sm.run_suite(sm.SAConfig(32, 32, "scalar"), wls)
    kans = sm.run_suite(sm.SAConfig(16, 16, "nm", N=4, M=8), wls)
    ratio = conv.cycles / kans.cycles
    us = (time.perf_counter() - t0) * 1e6 / (len(SIZES) + 1)
    rows.append(
        (
            "fig7.iso_area_runtime",
            us,
            f"cycles_ratio={ratio:.2f}x;paper=~2x;"
            f"kansas_util_min={min(float(r[2].split('kansas_util=')[1].split('%')[0]) for r in rows):.0f}%",
        )
    )
    return rows
