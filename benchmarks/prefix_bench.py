"""Paged-KV benchmark: prefix caching on a shared-prefix workload.

Workload A ("shared-prefix"): every request is ``system prompt (shared) +
short unique suffix`` — the system-prompt-heavy regime real serving lives
in.  The dense engine prefills and stores the shared prefix once *per
request*; the paged engine (``ServeConfig.paged``) prefills its blocks once
ever, and every later admission reuses them (``serve/prefix_cache.py``),
so ``prefill_into_pages`` computes only the unique suffix.

Workload B ("pr3"): the skewed output-length workload of
``benchmarks/serve_bench.py`` (PR 3's acceptance workload, no shared
prefixes) — run on both engines to show the paged read path does not
regress decode throughput where prefix caching cannot help.

Reported (``BENCH_prefix.json``, written by ``benchmarks/run.py``):

* ``prefill_tokens_saved`` / ``prefill_tokens_saved_ratio`` — total prompt
  tokens over tokens actually prefilled (counted from the schedule,
  deterministic; the acceptance gate wants >= 1.5x on workload A);
* ``prefix_block_hit_rate`` and ``blocks_in_use_watermark`` — cache
  efficacy and the pool's high-water mark vs. the dense row footprint;
* useful tokens/s per engine (interleaved best-of-repeats — wall clock on
  this host swings run to run, counted numbers do not).

``$KAN_SAS_BENCH_SMOKE=1`` shrinks shapes for CI.
"""

from __future__ import annotations

import os

import jax
import numpy as np


def _smoke() -> bool:
    return os.environ.get("KAN_SAS_BENCH_SMOKE", "") not in ("", "0")


def _workload():
    # Workload A is prefill-heavy by design: a long system prompt and a
    # short answer is exactly the regime prefix caching targets (the dense
    # engine spends most of its time re-prefilling the shared prefix).
    # The decode-heavy pr3 workload uses deeper chunks: the paged shadow
    # gather is amortized per chunk, so chunk depth is the relevant knob.
    if _smoke():
        return dict(n_requests=8, slots=2, max_new=4, prefix_len=40,
                    suffix=(2, 6), chunk_steps=2, reps=2, block_size=4,
                    pr3_chunk_steps=4, pr3_max_new=8, pr3_short=(1, 3),
                    pr3_prompt=(4, 10))
    return dict(n_requests=24, slots=4, max_new=8, prefix_len=96,
                suffix=(3, 12), chunk_steps=4, reps=3, block_size=8,
                pr3_chunk_steps=16, pr3_max_new=32, pr3_short=(1, 4),
                pr3_prompt=(4, 16))


def run() -> list[tuple[str, float, str]]:
    from repro import configs
    from repro.models import lm
    from repro.serve.engine import Engine, ServeConfig

    w = _workload()
    arch = configs.get_reduced("qwen1.5-0.5b")
    rs = np.random.RandomState(0)
    params = lm.init_params(jax.random.PRNGKey(0), arch.model)

    # ---- workload A: shared system prompt + unique suffixes ----
    system = rs.randint(0, arch.model.vocab, w["prefix_len"]).astype(np.int32)
    reqs_a = [
        np.concatenate([
            system,
            rs.randint(0, arch.model.vocab,
                       rs.randint(w["suffix"][0], w["suffix"][1] + 1)
                       ).astype(np.int32),
        ])
        for _ in range(w["n_requests"])
    ]
    max_seq = w["prefix_len"] + w["suffix"][1] + w["max_new"] + 8
    max_seq = -(-max_seq // w["block_size"]) * w["block_size"]
    dense = Engine(params, arch.model, ServeConfig(
        max_seq=max_seq, max_new_tokens=w["max_new"]))
    paged = Engine(params, arch.model, ServeConfig(
        max_seq=max_seq, max_new_tokens=w["max_new"], paged=True,
        block_size=w["block_size"]))

    def run_engine(eng, reqs, budgets=None, chunk_steps=None):
        def once():
            eng.serve_continuous(reqs, slots=w["slots"],
                                 chunk_steps=chunk_steps or w["chunk_steps"],
                                 seed=0, max_new=budgets)
            return dict(eng.last_serve_stats)
        return once

    total_prompt_a = int(sum(r.shape[0] for r in reqs_a))
    useful_a = float(w["n_requests"] * w["max_new"])
    # warm every shape once, then interleave timed repeats
    run_engine(dense, reqs_a)(), run_engine(paged, reqs_a)()
    st_d, st_p = None, None
    for _ in range(w["reps"]):
        d, p = run_engine(dense, reqs_a)(), run_engine(paged, reqs_a)()
        if st_d is None or d["wall_s"] < st_d["wall_s"]:
            st_d = d
        if st_p is None or p["wall_s"] < st_p["wall_s"]:
            st_p = p

    pstats = st_p["paged"]
    computed = pstats["prefill_tokens_computed"]
    saved = pstats["prefill_tokens_saved"]
    saved_ratio = total_prompt_a / max(computed, 1)
    dense_row = {
        "wall_s": st_d["wall_s"],
        "tokens_per_s": useful_a / st_d["wall_s"],
        "prefill_tokens_computed": total_prompt_a,   # dense always computes all
        "prefill_tokens_saved": 0,
        "mean_slot_utilization": st_d["mean_slot_utilization"],
        # dense HBM commitment: every slot preallocates a max_seq row
        "kv_token_slots_committed": w["slots"] * max_seq,
    }
    paged_row = {
        "wall_s": st_p["wall_s"],
        "tokens_per_s": useful_a / st_p["wall_s"],
        "prefill_tokens_computed": computed,
        "prefill_tokens_saved": saved,
        "prefill_tokens_saved_ratio": saved_ratio,
        "prefix_hit_rate": pstats["prefix_block_hit_rate"],
        "blocks_in_use_watermark": pstats["blocks_in_use_watermark"],
        "block_size": pstats["block_size"],
        "kv_token_slots_committed":
            pstats["blocks_in_use_watermark"] * pstats["block_size"],
        "n_preemptions": st_p["n_preemptions"],
        "mean_slot_utilization": st_p["mean_slot_utilization"],
    }

    # ---- workload B: PR 3's skewed output lengths, no shared prefixes ----
    reqs_b = [
        rs.randint(0, arch.model.vocab,
                   rs.randint(w["pr3_prompt"][0], w["pr3_prompt"][1] + 1)
                   ).astype(np.int32)
        for _ in range(w["n_requests"])
    ]
    budgets_b = [
        int(rs.randint(w["pr3_short"][0], w["pr3_short"][1] + 1))
        if rs.rand() < 0.75 else w["pr3_max_new"]
        for _ in range(w["n_requests"])
    ]
    useful_b = float(sum(budgets_b))
    cs = w["pr3_chunk_steps"]
    run_b_d = run_engine(dense, reqs_b, budgets_b, cs)
    run_b_p = run_engine(paged, reqs_b, budgets_b, cs)
    run_b_d(), run_b_p()     # warm
    # interleaved best-of (like workload A): host drift lands on both sides
    st_db, st_pb = None, None
    for _ in range(w["reps"]):
        db, pb = run_b_d(), run_b_p()
        if st_db is None or db["wall_s"] < st_db["wall_s"]:
            st_db = db
        if st_pb is None or pb["wall_s"] < st_pb["wall_s"]:
            st_pb = pb
    pr3 = {
        "dense_tokens_per_s": useful_b / st_db["wall_s"],
        "paged_tokens_per_s": useful_b / st_pb["wall_s"],
        "paged_over_dense": st_db["wall_s"] / st_pb["wall_s"],
        "chunk_steps": cs,
        # paged is OPT-IN: the dense engine (BENCH_serve.json, PR 3's
        # acceptance workload) is untouched by this subsystem, so workloads
        # without shared prefixes keep their tok/s; the paged column here
        # prices the per-chunk view gather the CPU fallback pays
        "note": "dense path unchanged; paged pays the block-gather on the "
                "jnp.take fallback (the TPU Pallas gather pipelines it)",
    }

    rep = {
        "workload": {
            "n_requests": w["n_requests"],
            "prefix_len": w["prefix_len"],
            "suffix_lens": [int(r.shape[0]) - w["prefix_len"] for r in reqs_a],
            "max_new": w["max_new"],
            "block_size": w["block_size"],
            "max_seq": max_seq,
            "smoke": _smoke(),
        },
        "engines": {"dense_prefix": dense_row, "paged_prefix": paged_row},
        "prefill_tokens_saved_ratio": saved_ratio,
        "pr3_workload": pr3,
    }
    run.last_report = rep  # type: ignore[attr-defined]
    return [
        ("prefix.dense", st_d["wall_s"] * 1e6,
         f"tok/s={dense_row['tokens_per_s']:.1f} prefill_toks={total_prompt_a}"),
        ("prefix.paged", st_p["wall_s"] * 1e6,
         f"tok/s={paged_row['tokens_per_s']:.1f} prefill_toks={computed} "
         f"saved_ratio=x{saved_ratio:.2f} "
         f"hit_rate={paged_row['prefix_hit_rate']:.2f}"),
        ("prefix.pr3_decode", st_pb["wall_s"] * 1e6,
         f"paged/dense tok/s ratio=x{pr3['paged_over_dense']:.2f}"),
    ]
