"""Benchmark harness: one module per paper table/figure (+ roofline).

Prints ``name,us_per_call,derived`` CSV (brief deliverable (d))."""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        app_utilization,
        arkane_compare,
        kan_paths,
        pe_energy,
        quant_accuracy,
        roofline,
        sa_sweep,
        workloads,
    )

    suites = [
        ("tableI", pe_energy),
        ("fig7", sa_sweep),
        ("fig8", app_utilization),
        ("secVB", arkane_compare),
        ("tableII", workloads),
        ("quant", quant_accuracy),
        ("kanpaths", kan_paths),
        ("roofline", roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in suites:
        try:
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},{derived}")
        except Exception:
            failures += 1
            print(f"{name}.ERROR,0,{traceback.format_exc(limit=1)!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
