"""Benchmark harness: one module per paper table/figure (+ roofline).

Prints ``name,us_per_call,derived`` CSV (brief deliverable (d)) and writes
``BENCH_kan_paths.json`` (µs per KAN path + modeled HBM bytes + autotuned
tile choices) so future PRs have a perf trajectory to compare against."""

from __future__ import annotations

import json
import os
import sys
import traceback

KAN_PATHS_JSON = os.path.join(os.path.dirname(__file__), "..",
                              "BENCH_kan_paths.json")


def main() -> None:
    from benchmarks import (
        app_utilization,
        arkane_compare,
        kan_paths,
        pe_energy,
        quant_accuracy,
        roofline,
        sa_sweep,
        workloads,
    )

    suites = [
        ("tableI", pe_energy),
        ("fig7", sa_sweep),
        ("fig8", app_utilization),
        ("secVB", arkane_compare),
        ("tableII", workloads),
        ("quant", quant_accuracy),
        ("kanpaths", kan_paths),
        ("roofline", roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in suites:
        try:
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},{derived}")
        except Exception:
            failures += 1
            print(f"{name}.ERROR,0,{traceback.format_exc(limit=1)!r}")
    rep = getattr(kan_paths.run, "last_report", None)
    if rep is not None:
        out = os.path.abspath(KAN_PATHS_JSON)
        with open(out, "w") as f:
            json.dump(rep, f, indent=2)
        print(f"# wrote {out}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
