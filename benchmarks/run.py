"""Benchmark harness: one module per paper table/figure (+ roofline).

Prints ``name,us_per_call,derived`` CSV (brief deliverable (d)) and writes
``BENCH_kan_paths.json`` (µs per KAN path + modeled HBM bytes + autotuned
tile choices) so future PRs have a perf trajectory to compare against.

``--smoke`` runs only the kanpaths suite at reduced shapes (sets
``$KAN_SAS_BENCH_SMOKE=1``) and *fails* unless the written JSON carries the
sparse-path rows — the CI gate that keeps the N:M sparse datapath in the
perf trajectory."""

from __future__ import annotations

import json
import os
import sys
import traceback

KAN_PATHS_JSON = os.path.join(os.path.dirname(__file__), "..",
                              "BENCH_kan_paths.json")


def _check_sparse_rows(rep: dict) -> list[str]:
    """The sparse-path rows every report must carry (CI smoke gate)."""
    problems = []
    if "sparse_kernel" not in rep.get("paths", {}):
        problems.append("paths.sparse_kernel missing")
    decode_rows = rep.get("decode", {}).get("rows", {})
    if not decode_rows:
        problems.append("decode.rows missing")
    for bs_key, row in decode_rows.items():
        if "sparse" not in row:
            problems.append(f"decode.rows[{bs_key}].sparse missing")
    if "sparse_coeff_cut_vs_fused" not in rep:
        problems.append("sparse_coeff_cut_vs_fused missing")
    return problems


def main() -> None:
    smoke = "--smoke" in sys.argv
    if smoke:
        os.environ["KAN_SAS_BENCH_SMOKE"] = "1"

    from benchmarks import (
        app_utilization,
        arkane_compare,
        kan_paths,
        pe_energy,
        quant_accuracy,
        roofline,
        sa_sweep,
        workloads,
    )

    suites = [
        ("tableI", pe_energy),
        ("fig7", sa_sweep),
        ("fig8", app_utilization),
        ("secVB", arkane_compare),
        ("tableII", workloads),
        ("quant", quant_accuracy),
        ("kanpaths", kan_paths),
        ("roofline", roofline),
    ]
    if smoke:
        suites = [("kanpaths", kan_paths)]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in suites:
        try:
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},{derived}")
        except Exception:
            failures += 1
            print(f"{name}.ERROR,0,{traceback.format_exc(limit=1)!r}")
    rep = getattr(kan_paths.run, "last_report", None)
    if rep is not None:
        out = os.path.abspath(KAN_PATHS_JSON)
        with open(out, "w") as f:
            json.dump(rep, f, indent=2)
        print(f"# wrote {out}")
        missing = _check_sparse_rows(rep)
        if missing:
            failures += 1
            print(f"# SPARSE ROWS MISSING: {missing}")
    elif smoke:
        failures += 1
        print("# kanpaths produced no report — BENCH_kan_paths.json not written")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
