"""Benchmark harness: one module per paper table/figure (+ roofline).

Prints ``name,us_per_call,derived`` CSV (brief deliverable (d)) and writes
``BENCH_kan_paths.json`` (µs per KAN path + modeled HBM bytes + autotuned
tile choices) so future PRs have a perf trajectory to compare against.

``--smoke`` runs the kanpaths, serving, prefix-cache, and mesh-sharding
suites at reduced shapes (sets ``$KAN_SAS_BENCH_SMOKE=1``) and *fails*
unless the written JSONs carry the sparse-path rows
(``BENCH_kan_paths.json``), the continuous-engine rows
(``BENCH_serve.json``), the paged-engine rows (``BENCH_prefix.json``),
both mesh columns (``BENCH_shard.json``), and the speculative rows
(``BENCH_spec.json``) — the CI gates that keep the N:M sparse datapath,
the continuous-batching engine, the paged KV subsystem, mesh-native
serving, and the drafter+verify engine in the perf trajectory."""

from __future__ import annotations

import json
import os
import sys
import traceback

KAN_PATHS_JSON = os.path.join(os.path.dirname(__file__), "..",
                              "BENCH_kan_paths.json")
SERVE_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
PREFIX_JSON = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_prefix.json")
SHARD_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_shard.json")
SPEC_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_spec.json")


def _check_sparse_rows(rep: dict) -> list[str]:
    """The sparse-path rows every report must carry (CI smoke gate)."""
    problems = []
    if "sparse_kernel" not in rep.get("paths", {}):
        problems.append("paths.sparse_kernel missing")
    decode_rows = rep.get("decode", {}).get("rows", {})
    if not decode_rows:
        problems.append("decode.rows missing")
    for bs_key, row in decode_rows.items():
        if "sparse" not in row:
            problems.append(f"decode.rows[{bs_key}].sparse missing")
    if "sparse_coeff_cut_vs_fused" not in rep:
        problems.append("sparse_coeff_cut_vs_fused missing")
    return problems


def _check_serve_rows(rep: dict) -> list[str]:
    """The continuous-engine rows every serving report must carry (CI smoke
    gate): without them the perf trajectory silently loses the
    static-vs-continuous comparison."""
    problems = []
    engines = rep.get("engines", {})
    for eng in ("static", "continuous"):
        if eng not in engines:
            problems.append(f"engines.{eng} missing")
            continue
        for key in ("tokens_per_s", "mean_slot_utilization",
                    "p50_latency_s", "p95_latency_s"):
            if key not in engines[eng]:
                problems.append(f"engines.{eng}.{key} missing")
    if "continuous_speedup_tokens_per_s" not in rep:
        problems.append("continuous_speedup_tokens_per_s missing")
    return problems


def _check_prefix_rows(rep: dict) -> list[str]:
    """The paged-engine rows every prefix report must carry (CI smoke
    gate): without them the trajectory silently loses the paged-vs-dense
    comparison and the prefill-tokens-saved acceptance metric."""
    problems = []
    engines = rep.get("engines", {})
    if "dense_prefix" not in engines:
        problems.append("engines.dense_prefix missing")
    paged = engines.get("paged_prefix")
    if paged is None:
        problems.append("engines.paged_prefix missing")
    else:
        for key in ("tokens_per_s", "prefill_tokens_saved",
                    "prefill_tokens_saved_ratio", "prefix_hit_rate",
                    "blocks_in_use_watermark"):
            if key not in paged:
                problems.append(f"engines.paged_prefix.{key} missing")
    if "prefill_tokens_saved_ratio" not in rep:
        problems.append("prefill_tokens_saved_ratio missing")
    if "pr3_workload" not in rep:
        problems.append("pr3_workload missing")
    return problems


def _check_shard_rows(rep: dict) -> list[str]:
    """The mesh rows every sharding report must carry (CI smoke gate):
    without BOTH mesh columns the trajectory silently loses the
    sharded-vs-single-device comparison."""
    problems = []
    meshes = rep.get("meshes", {})
    for name in ("1x1", "2x4"):
        if name not in meshes:
            problems.append(f"meshes.{name} missing")
            continue
        for key in ("tokens_per_s", "params_bytes_per_device",
                    "pool_bytes_per_device"):
            if key not in meshes[name]:
                problems.append(f"meshes.{name}.{key} missing")
    for key in ("params_bytes_cut_per_device", "tokens_per_s_ratio"):
        if key not in rep:
            problems.append(f"{key} missing")
    return problems


def _check_spec_rows(rep: dict) -> list[str]:
    """The speculative rows every spec report must carry (CI smoke gate):
    without them the trajectory silently loses the drafter+verify engine
    and the acceptance-rate/useful-tok/s comparison vs spec_k=0."""
    problems = []
    if "tokens_per_s" not in rep.get("baseline", {}):
        problems.append("baseline.tokens_per_s missing")
    spec = rep.get("spec", {})
    if not spec:
        problems.append("spec rows missing")
    for name, row in spec.items():
        for key in ("tokens_per_s", "acceptance_rate",
                    "speedup_vs_baseline", "windows"):
            if key not in row:
                problems.append(f"spec.{name}.{key} missing")
    if "speedup_vs_baseline" not in rep.get("best", {}):
        problems.append("best.speedup_vs_baseline missing")
    if rep.get("programs_after_warmup"):
        problems.append(
            f"programs_after_warmup not empty: {rep['programs_after_warmup']}")
    return problems


def main() -> None:
    smoke = "--smoke" in sys.argv
    if smoke:
        os.environ["KAN_SAS_BENCH_SMOKE"] = "1"

    from benchmarks import (
        app_utilization,
        arkane_compare,
        kan_paths,
        pe_energy,
        prefix_bench,
        quant_accuracy,
        roofline,
        sa_sweep,
        serve_bench,
        shard_bench,
        spec_bench,
        workloads,
    )

    suites = [
        ("tableI", pe_energy),
        ("fig7", sa_sweep),
        ("fig8", app_utilization),
        ("secVB", arkane_compare),
        ("tableII", workloads),
        ("quant", quant_accuracy),
        ("kanpaths", kan_paths),
        ("serve", serve_bench),
        ("prefix", prefix_bench),
        ("shard", shard_bench),
        ("spec", spec_bench),
        ("roofline", roofline),
    ]
    if smoke:
        suites = [("kanpaths", kan_paths), ("serve", serve_bench),
                  ("prefix", prefix_bench), ("shard", shard_bench),
                  ("spec", spec_bench)]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in suites:
        try:
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},{derived}")
        except Exception:
            failures += 1
            print(f"{name}.ERROR,0,{traceback.format_exc(limit=1)!r}")
    gates = [
        (kan_paths, KAN_PATHS_JSON, _check_sparse_rows, "SPARSE"),
        (serve_bench, SERVE_JSON, _check_serve_rows, "SERVE"),
        (prefix_bench, PREFIX_JSON, _check_prefix_rows, "PREFIX"),
        (shard_bench, SHARD_JSON, _check_shard_rows, "SHARD"),
        (spec_bench, SPEC_JSON, _check_spec_rows, "SPEC"),
    ]
    for mod, json_path, checker, label in gates:
        rep = getattr(mod.run, "last_report", None)
        if rep is not None:
            out = os.path.abspath(json_path)
            with open(out, "w") as f:
                json.dump(rep, f, indent=2)
            print(f"# wrote {out}")
            missing = checker(rep)
            if missing:
                failures += 1
                print(f"# {label} ROWS MISSING: {missing}")
        elif smoke:
            failures += 1
            print(f"# {mod.__name__} produced no report — "
                  f"{os.path.basename(json_path)} not written")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
