"""Speculative-decoding benchmark: the drafter+verify engine vs the plain
continuous engine on the serve_bench skewed-output-length workload
(``BENCH_spec.json``).

Model: a 6-repeat reduced kanformer whose LATER repeats' output projections
(``attn.wo``, ``kan.c2``/``kan.b2``) are damped by a small factor, so the
residual stream — and the argmax — is dominated by the first repeats.  That
makes the derived shallow drafter (``DraftModel.from_target``: the first
``draft_layers`` repeats, sharing embed/unembed) a *good* approximation of
the target, which is the regime speculation is built for.

Two speedup columns, deliberately separate:

- ``speedup_vs_baseline`` — *counted* useful tokens per full-depth target
  pass, from the deterministic schedule: a window costs
  ``1 + k * draft_layers / n_repeats`` pass-equivalents (one fused verify
  + k drafter steps at ``draft_layers/n_repeats`` depth each) and emits up
  to ``k+1`` tokens.  This is the metric that transfers to the paper's
  regime, where decode is weight-streaming-bound and a fused k+1-position
  verify pass costs about one sequential step on the systolic array.
- ``wall_speedup_vs_baseline`` — host wall clock.  On this CPU it sits
  BELOW 1x and that is expected, not a bug: the KAN row cost here is
  linear in rows (measured: a 9-position ``verify_window`` costs ~9x one
  ``decode_step``), so batching the verify buys nothing and speculation
  pays the drafter on top.  Same honesty policy as ``BENCH_shard.json``'s
  x0.16 tok/s: the host prices overhead, the counted column prices the
  design.

Outputs are bit-identical across every row (the §9 contract, enforced by
``tests/test_speculative.py``) and asserted again here.  Timings are
interleaved best-of-repeats; each engine warms its shapes first and the
retrace sentinel (``programs_after_warmup``) must stay empty.

``$KAN_SAS_BENCH_SMOKE=1`` shrinks the sweep and budgets for CI.
"""

from __future__ import annotations

import os
import time

import jax
import numpy as np

N_REPEATS = 6

# later-repeat output projections scaled by this factor: front-loads the
# model so a 1-2 repeat drafter tracks the 6-repeat target's argmax
FRONT_LOAD = 0.05


def _smoke() -> bool:
    return os.environ.get("KAN_SAS_BENCH_SMOKE", "") not in ("", "0")


def _workload():
    if _smoke():
        return dict(n_requests=8, slots=2, max_new=12, short=(2, 5),
                    prompt_lo=4, prompt_hi=10, chunk_steps=4, reps=2,
                    sweep=[(2, 1), (4, 1)])
    return dict(n_requests=16, slots=4, max_new=32, short=(2, 8),
                prompt_lo=4, prompt_hi=16, chunk_steps=8, reps=3,
                sweep=[(2, 1), (4, 1), (8, 1), (2, 2), (4, 2), (8, 2)])


def _front_loaded_params(model):
    """init_params, then damp every repeat-after-the-first's contribution
    to the residual stream (each block ADDS ``attn(x)`` and ``kan(x)``;
    scaling their output projections scales exactly that addition)."""
    from repro.models import lm

    params = lm.init_params(jax.random.PRNGKey(0), model)
    unit = []
    for blk in params["unit"]:
        blk = jax.tree.map(lambda a: a, blk)          # shallow copy tree
        for grp, names in (("attn", ("wo",)), ("kan", ("c2", "b2"))):
            for name in names:
                leaf = blk[grp][name]
                blk[grp][name] = leaf.at[1:].multiply(FRONT_LOAD)
        unit.append(blk)
    params["unit"] = unit
    return params


def run() -> list[tuple[str, float, str]]:
    from repro.configs import kanformer_100m
    from repro.serve.engine import Engine, ServeConfig

    w = _workload()
    arch = kanformer_100m.build(n_layers=N_REPEATS, d_model=64, n_heads=4,
                                n_kv=4, kan_ff=96, vocab=512)
    model = arch.model
    params = _front_loaded_params(model)

    rs = np.random.RandomState(0)
    requests = [
        rs.randint(1, model.vocab,
                   rs.randint(w["prompt_lo"], w["prompt_hi"] + 1)).astype(np.int32)
        for _ in range(w["n_requests"])
    ]
    budgets = [
        int(rs.randint(w["short"][0], w["short"][1] + 1))
        if rs.rand() < 0.75 else w["max_new"]
        for _ in range(w["n_requests"])
    ]
    useful = float(sum(budgets))
    max_seq = w["prompt_hi"] + w["max_new"] + 8
    max_seq = -(-max_seq // 8) * 8

    def make_engine(spec_k=0, draft_layers=1):
        return Engine(params, model, ServeConfig(
            max_seq=max_seq, max_new_tokens=w["max_new"],
            paged=True, block_size=8,
            spec_k=spec_k, draft_layers=draft_layers,
        ))

    def timed(eng):
        t0 = time.time()
        outs = eng.serve_continuous(requests, slots=w["slots"],
                                    chunk_steps=w["chunk_steps"], seed=0,
                                    max_new=budgets)
        wall = time.time() - t0
        return wall, outs, dict(eng.last_serve_stats)

    # one engine per row (spec_k/draft_layers recompile anyway); warm every
    # shape once, then interleave timed repeats across all engines and keep
    # each row's best wall
    engines = {"baseline": make_engine()}
    for k, dl in w["sweep"]:
        engines[f"k{k}_draft{dl}"] = make_engine(spec_k=k, draft_layers=dl)

    warm, outs_by_row = {}, {}
    for name, eng in engines.items():
        _, outs, _ = timed(eng)
        outs_by_row[name] = outs
        warm[name] = {n: s["programs"]
                      for n, s in eng.compiles.snapshot().items()}
    # the §9 contract, spot-checked here too: every row emits the same ids
    for name, outs in outs_by_row.items():
        for a, b in zip(outs_by_row["baseline"], outs):
            assert (a == b).all(), f"{name} diverged from baseline outputs"

    best: dict[str, dict] = {}
    for _ in range(w["reps"]):
        for name, eng in engines.items():
            wall, _, stats = timed(eng)
            if name not in best or wall < best[name]["wall_s"]:
                row = {"wall_s": wall, "tokens_per_s": useful / wall,
                       "mean_slot_utilization": stats["mean_slot_utilization"],
                       "chunks_run": stats["chunks_run"]}
                if "spec" in stats:
                    sp = stats["spec"]
                    row.update(spec_k=sp["spec_k"],
                               draft_layers=sp["draft_layers"],
                               windows=sp["windows"],
                               acceptance_rate=sp["acceptance_rate"],
                               emitted_tokens=sp["emitted_tokens"])
                best[name] = row

    retraced: dict[str, int] = {}
    for name, eng in engines.items():
        end = {n: s["programs"] for n, s in eng.compiles.snapshot().items()}
        for n in end:
            if end[n] != warm[name].get(n, 0):
                retraced[f"{name}.{n}"] = end[n] - warm[name].get(n, 0)

    # counted pass accounting (deterministic, from the schedule): a window
    # costs one full-depth verify pass + k drafter steps at dl/L depth per
    # slot; a baseline chunk costs chunk_steps passes per slot.  Window
    # emissions exclude the admission-prefill token, so subtract the same
    # n_requests first tokens from the baseline's credit.
    brow = best["baseline"]
    base_passes = brow["chunks_run"] * w["chunk_steps"] * w["slots"]
    base_tpp = (useful - w["n_requests"]) / base_passes
    brow["target_pass_equivalents"] = base_passes
    brow["useful_tokens_per_pass"] = base_tpp
    base_tps = brow["tokens_per_s"]
    for name, row in best.items():
        if name == "baseline":
            continue
        cost = 1.0 + row["spec_k"] * row["draft_layers"] / N_REPEATS
        passes = row["windows"] * w["slots"] * cost
        row["target_pass_equivalents"] = passes
        row["useful_tokens_per_pass"] = row["emitted_tokens"] / passes
        row["speedup_vs_baseline"] = row["useful_tokens_per_pass"] / base_tpp
        row["wall_speedup_vs_baseline"] = row["tokens_per_s"] / base_tps
    spec_rows = {n: r for n, r in best.items() if n != "baseline"}
    best_row = max(spec_rows, key=lambda n: spec_rows[n]["speedup_vs_baseline"])

    rep = {
        "workload": {
            "n_requests": w["n_requests"],
            "max_new": w["max_new"],
            "budgets": budgets,
            "prompt_lens": [int(r.shape[0]) for r in requests],
            "skew": "75% short / 25% full-budget outputs",
            "front_load_factor": FRONT_LOAD,
            "model": f"kanformer {N_REPEATS}x(d64,h4,kv4,ff96) vocab512, "
                     "front-loaded",
            "smoke": _smoke(),
        },
        "baseline": brow,
        "spec": spec_rows,
        "best": {"row": best_row,
                 "speedup_vs_baseline":
                     spec_rows[best_row]["speedup_vs_baseline"],
                 "wall_speedup_vs_baseline":
                     spec_rows[best_row]["wall_speedup_vs_baseline"]},
        "speedup_metric": (
            "useful tokens per full-depth target pass, counted from the "
            "schedule (window = 1 verify pass + k*draft_layers/"
            f"{N_REPEATS} drafter passes); wall_* columns are host wall "
            "clock, which on this CPU is row-linear (a k+1-position verify "
            "costs ~k+1 decode steps) and therefore expected < 1x — see "
            "module docstring / DESIGN.md §9"),
        "outputs_bit_identical": True,   # asserted above, every row
        "programs_after_warmup": retraced,
    }
    run.last_report = rep  # type: ignore[attr-defined]

    out = [("spec.baseline", brow["wall_s"] * 1e6,
            f"tok/s={base_tps:.1f} tok/pass={base_tpp:.2f}")]
    for name, row in spec_rows.items():
        out.append((f"spec.{name}", row["wall_s"] * 1e6,
                    f"acc={row['acceptance_rate']:.3f} "
                    f"tok/pass={row['useful_tokens_per_pass']:.2f} "
                    f"x{row['speedup_vs_baseline']:.2f} "
                    f"(wall x{row['wall_speedup_vs_baseline']:.2f})"))
    out.append(("spec.best", 0.0,
                f"{best_row} x{rep['best']['speedup_vs_baseline']:.2f} "
                f"counted tok/pass "
                f"retraced_after_warmup={sum(retraced.values())}"))
    return out
