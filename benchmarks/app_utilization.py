"""Paper Fig 8: per-application PE utilization at iso-area —
KAN-SAs 16x16 (0.47 mm^2) vs conventional scalar SA 32x32 (0.50 mm^2),
per-application (G, P) from Table II.

Paper anchors: MNIST-KAN 30% vs 99.25%; average improvement 39.9 points,
max 69.3 points."""

import time

from repro.core import sa_model as sm


def run() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    apps = sm.paper_workloads(64)
    rows = []
    imps = []
    for name, ws in apps.items():
        M = max(w.M for w in ws)
        N = max(w.N for w in ws)
        conv = sm.run_suite(sm.SAConfig(32, 32, "scalar"), ws)
        kans = sm.run_suite(sm.SAConfig(16, 16, "nm", N=N, M=M), ws)
        imp = (kans.utilization - conv.utilization) * 100
        imps.append(imp)
        rows.append(
            (
                f"fig8.{name}",
                0.0,
                f"conv={conv.utilization*100:.1f}%;kansas={kans.utilization*100:.2f}%;"
                f"improvement={imp:.1f}pts",
            )
        )
    us = (time.perf_counter() - t0) * 1e6 / len(apps)
    rows.append(
        (
            "fig8.summary",
            us,
            f"avg_improvement={sum(imps)/len(imps):.1f}pts(paper=39.9);"
            f"max={max(imps):.1f}pts(paper=69.3)",
        )
    )
    return rows
