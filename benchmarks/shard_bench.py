"""Mesh-serving benchmark: 1-device engine vs an 8-fake-CPU-device
(2x4 data x model) mesh — tok/s plus **per-device HBM-resident param +
KV-pool bytes** (``BENCH_shard.json``, written by ``benchmarks/run.py``).

The point on a CPU host is the MEMORY column, not the speed column: the
8 fake devices share one physical CPU, so the sharded engine pays real
collective/reshard overhead while gaining zero parallel FLOPs — tok/s
ratio < 1 is expected here and is exactly the resharding cost DESIGN.md §4
tabulates.  What the mesh buys is the per-device footprint: params shard
``model``-axis dimensions 4-way and the paged block pool shards blocks
2-way / kv_heads 4-way, so each device holds a fraction of the weights and
of the KV pool — the capacity lever that lets one serving process span
chips whose HBM a replicated model would blow.

Runs in a SUBPROCESS because ``--xla_force_host_platform_device_count``
must be set before jax initialises (the harness process already holds a
1-device jax).  Timings are interleaved best-of-repeats (host wall clock
swings 2-3x); byte counts are exact (summed ``addressable_shards`` on
device 0).

``$KAN_SAS_BENCH_SMOKE=1`` shrinks request count/budgets for CI.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap


def _smoke() -> bool:
    return os.environ.get("KAN_SAS_BENCH_SMOKE", "") not in ("", "0")


_SCRIPT = textwrap.dedent(
    """
    import json, os, time
    import jax, numpy as np
    from repro import configs
    from repro.models import lm
    from repro.serve.engine import Engine, ServeConfig
    from repro.launch.mesh import make_host_mesh

    smoke = os.environ.get("KAN_SAS_BENCH_SMOKE", "") not in ("", "0")
    n_requests, max_new, reps = (8, 6, 2) if smoke else (16, 24, 3)
    slots, chunk_steps, bs = 4, 4, 8
    arch = configs.get_reduced("kanformer-100m")
    max_seq = 48 if smoke else 80
    pool_blocks = slots * (max_seq // bs) + 2   # even: the data axis divides

    rs = np.random.RandomState(0)
    requests = [
        rs.randint(0, arch.model.vocab, rs.randint(4, 13)).astype(np.int32)
        for _ in range(n_requests)
    ]
    params = lm.init_params(jax.random.PRNGKey(0), arch.model)

    def bytes_on_dev0(tree):
        dev = jax.devices()[0]
        return int(sum(
            s.data.nbytes
            for leaf in jax.tree.leaves(tree)
            for s in leaf.addressable_shards if s.device == dev
        ))

    def build(mesh):
        return Engine(params, arch.model, ServeConfig(
            max_seq=max_seq, max_new_tokens=max_new, paged=True,
            block_size=bs, pool_blocks=pool_blocks, mesh=mesh))

    engines = {
        "1x1": build(None),                    # today's single-device engine
        "2x4": build(make_host_mesh((2, 4))),  # data=2 x model=4 mesh
    }

    def serve(eng):
        eng.serve_continuous(list(requests), slots=slots,
                             chunk_steps=chunk_steps, seed=0)
        return dict(eng.last_serve_stats)

    rows = {}
    for name, eng in engines.items():
        serve(eng)                             # warm every jitted shape
    stats = {name: None for name in engines}
    for _ in range(reps):                      # interleaved best-of-repeats
        for name, eng in engines.items():
            s = serve(eng)
            if stats[name] is None or s["wall_s"] < stats[name]["wall_s"]:
                stats[name] = s
    for name, eng in engines.items():
        s = stats[name]
        pool = eng._make_paged_caches(pool_blocks, bs)
        rows[name] = {
            "mesh_shape": s["mesh_shape"],
            "devices": eng.shard.n_devices if eng.shard else 1,
            "wall_s": s["wall_s"],
            "useful_tokens": s["useful_tokens"],
            "tokens_per_s": s["useful_tokens"] / s["wall_s"],
            "params_bytes_per_device": bytes_on_dev0(eng.params),
            "pool_bytes_per_device": bytes_on_dev0(pool),
        }
        del pool
    print("RESULT " + json.dumps(rows))
    """
)


def run() -> list[tuple[str, float, str]]:
    env = {
        "PYTHONPATH": "src", "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
        "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    }
    if _smoke():
        env["KAN_SAS_BENCH_SMOKE"] = "1"
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=1800, env=env, cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    if proc.returncode != 0:
        raise RuntimeError(f"shard_bench subprocess failed:\n{proc.stderr[-3000:]}")
    line = next(l for l in proc.stdout.splitlines() if l.startswith("RESULT "))
    rows = json.loads(line[len("RESULT "):])

    one, sharded = rows["1x1"], rows["2x4"]
    param_cut = one["params_bytes_per_device"] / sharded["params_bytes_per_device"]
    pool_cut = one["pool_bytes_per_device"] / sharded["pool_bytes_per_device"]
    rep = {
        "workload": {"arch": "kanformer-100m (reduced)", "paged": True,
                     "smoke": _smoke()},
        "meshes": rows,
        "params_bytes_cut_per_device": param_cut,
        "pool_bytes_cut_per_device": pool_cut,
        "tokens_per_s_ratio": sharded["tokens_per_s"] / one["tokens_per_s"],
        "note": "8 fake devices share one CPU: the ratio prices collective "
                "overhead with zero parallel-FLOP gain; the bytes columns "
                "are the capacity win (DESIGN.md §4).",
    }
    run.last_report = rep  # type: ignore[attr-defined]
    return [
        ("shard.1x1", one["wall_s"] * 1e6,
         f"tok/s={one['tokens_per_s']:.1f} "
         f"param_B/dev={one['params_bytes_per_device']}"),
        ("shard.2x4", sharded["wall_s"] * 1e6,
         f"tok/s={sharded['tokens_per_s']:.1f} "
         f"param_B/dev={sharded['params_bytes_per_device']}"),
        ("shard.cut", 0.0,
         f"param_bytes/dev x{param_cut:.2f}, pool_bytes/dev x{pool_cut:.2f}, "
         f"tok/s x{rep['tokens_per_s_ratio']:.2f}"),
    ]
