"""Serving benchmark: static bucket draining vs slot-level continuous
batching on a *skewed output-length* workload — the regime where the static
engine's idle-slot problem (the software analogue of the paper's idle-PE
problem) is worst.

Workload: mixed prompt lengths, per-request token budgets drawn from a
skewed mixture (most requests want a few tokens, a minority want the full
``max_new``).  The static engine must drain every bucket to the global
``max_new`` — short requests keep decoding into dead slots — while the
continuous engine retires a slot the moment its budget is met and admits
the next queued request at the following chunk boundary.

Reported per engine (``BENCH_serve.json``, written by ``benchmarks/run.py``):
useful tokens/s, mean slot utilization (useful token-steps over slot x step
capacity), and p50/p95 request latency.  Wall-clock on this host swings
2-3x run to run, so engines are timed interleaved best-of-repeats; the
utilization numbers are *counted* from the schedule and are deterministic.

``$KAN_SAS_BENCH_SMOKE=1`` shrinks the request count and budgets for CI.
"""

from __future__ import annotations

import os

import jax
import numpy as np


def _smoke() -> bool:
    return os.environ.get("KAN_SAS_BENCH_SMOKE", "") not in ("", "0")


def _workload():
    """Skewed regime: most requests want a handful of tokens, the minority
    want a long tail.  ``max_new`` is deliberately deep — bucket draining
    costs the static engine ``max_new`` steps *per row* regardless of
    budget, which is exactly the waste continuous batching reclaims (and
    the regime real decode serving lives in; at trivial depths per-dispatch
    host overhead hides the effect on this CPU host)."""
    if _smoke():
        return dict(n_requests=8, batch=2, max_new=8, short=(1, 3),
                    prompt_lo=4, prompt_hi=10, chunk_steps=2, reps=2)
    return dict(n_requests=24, batch=4, max_new=48, short=(1, 4),
                prompt_lo=4, prompt_hi=16, chunk_steps=8, reps=3)


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q))


def _static_utilization(n_requests, batch, budgets, max_new):
    """Counted, not timed: every bucket row (including duplicate-padded
    rows) decodes ``max_new`` tokens; only each request's budget is kept."""
    n_buckets = -(-n_requests // batch)
    return float(sum(budgets)) / float(n_buckets * batch * max_new)


def run() -> list[tuple[str, float, str]]:
    from repro import configs
    from repro.models import lm
    from repro.serve.engine import Engine, ServeConfig

    w = _workload()
    arch = configs.get_reduced("qwen1.5-0.5b")
    rs = np.random.RandomState(0)
    requests = [
        rs.randint(0, arch.model.vocab,
                   rs.randint(w["prompt_lo"], w["prompt_hi"] + 1)).astype(np.int32)
        for _ in range(w["n_requests"])
    ]
    # skewed budgets: 75% short, 25% want the full max_new
    budgets = [
        int(rs.randint(w["short"][0], w["short"][1] + 1))
        if rs.rand() < 0.75 else w["max_new"]
        for _ in range(w["n_requests"])
    ]
    params = lm.init_params(jax.random.PRNGKey(0), arch.model)
    eng = Engine(params, arch.model, ServeConfig(
        max_seq=w["prompt_hi"] + w["max_new"] + 8,
        max_new_tokens=w["max_new"],
    ))
    useful = float(sum(budgets))

    def run_static():
        eng.serve_requests(requests, batch_size=w["batch"], seed=0)
        return dict(eng.last_serve_stats)

    def run_continuous():
        eng.serve_continuous(requests, slots=w["batch"],
                             chunk_steps=w["chunk_steps"], seed=0,
                             max_new=budgets)
        return dict(eng.last_serve_stats)

    # warm every jitted shape once, then interleave timed repeats and keep
    # the best wall per engine (host timings swing 2-3x run to run)
    run_static(), run_continuous()
    warm_programs = {
        n: s["programs"] for n, s in eng.compiles.snapshot().items()
    }
    st, ct = None, None
    for _ in range(w["reps"]):
        s, c = run_static(), run_continuous()
        if st is None or s["wall_s"] < st["wall_s"]:
            st = s
        if ct is None or c["wall_s"] < ct["wall_s"]:
            ct = c
    # retrace sentinel: the warmup pass must have compiled every program the
    # timed repeats run — a program appearing here means a shape leaked into
    # a traced argument and a timed rep paid an XLA compile
    end_programs = {
        n: s["programs"] for n, s in eng.compiles.snapshot().items()
    }
    retraced = {
        n: end_programs[n] - warm_programs.get(n, 0)
        for n in end_programs
        if end_programs[n] != warm_programs.get(n, 0)
    }

    static_row = {
        "wall_s": st["wall_s"],
        "useful_tokens": useful,
        "tokens_per_s": useful / st["wall_s"],
        "mean_slot_utilization": _static_utilization(
            w["n_requests"], w["batch"], budgets, w["max_new"]),
        "p50_latency_s": _percentile(st["request_latency_s"], 50),
        "p95_latency_s": _percentile(st["request_latency_s"], 95),
        "batch": w["batch"],
    }
    cont_row = {
        "wall_s": ct["wall_s"],
        "useful_tokens": useful,
        "tokens_per_s": useful / ct["wall_s"],
        "mean_slot_utilization": ct["mean_slot_utilization"],
        "p50_latency_s": _percentile(ct["request_latency_s"], 50),
        "p95_latency_s": _percentile(ct["request_latency_s"], 95),
        "slots": w["batch"],
        "chunk_steps": w["chunk_steps"],
        "chunks_run": ct["chunks_run"],
        "n_served": ct["n_served"],
    }
    rep = {
        "workload": {
            "n_requests": w["n_requests"],
            "max_new": w["max_new"],
            "budgets": budgets,
            "prompt_lens": [int(r.shape[0]) for r in requests],
            "skew": "75% short / 25% full-budget outputs",
            "smoke": _smoke(),
        },
        "engines": {"static": static_row, "continuous": cont_row},
        # compile/retrace counters (kanlint retrace sentinel): distinct
        # compiled programs + total traces per jitted entry point, and any
        # programs compiled AFTER warmup (must stay empty)
        "compiles": eng.compiles.snapshot(),
        "programs_after_warmup": retraced,
        "continuous_speedup_tokens_per_s":
            cont_row["tokens_per_s"] / static_row["tokens_per_s"],
        "continuous_utilization_gain":
            cont_row["mean_slot_utilization"]
            / static_row["mean_slot_utilization"],
    }
    run.last_report = rep  # type: ignore[attr-defined]
    return [
        ("serve.static", st["wall_s"] * 1e6,
         f"tok/s={static_row['tokens_per_s']:.1f} "
         f"util={static_row['mean_slot_utilization']:.3f}"),
        ("serve.continuous", ct["wall_s"] * 1e6,
         f"tok/s={cont_row['tokens_per_s']:.1f} "
         f"util={cont_row['mean_slot_utilization']:.3f}"),
        ("serve.speedup", 0.0,
         f"x{rep['continuous_speedup_tokens_per_s']:.2f} tok/s, "
         f"x{rep['continuous_utilization_gain']:.2f} utilization"),
        ("serve.compiles", 0.0,
         f"programs={sum(end_programs.values())} "
         f"retraced_after_warmup={sum(retraced.values())}"),
    ]
