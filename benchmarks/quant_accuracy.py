"""Paper §V accuracy claim: "<1% accuracy drop for all the models (e.g.,
MNIST-KAN drops from 96.58% to 96.0%)".

Offline container -> MNIST stand-in is the synthetic class-conditional set
from data/pipeline.py (labelled as such). We train the paper's MNIST-KAN
[784, 64, 10] (G=10, P=3), then quantise every layer to the int8 LUT
datapath (core/quantization.py) and report the fp32 vs int8 accuracy gap —
the claim under test is the GAP, not the absolute number."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kan_layer as kl
from repro.core import quantization as q
from repro.data import pipeline as dp


def train_mnist_kan(steps=250, bs=256, lr=3e-3, seed=0, G=10, P=3,
                    layers=(784, 64, 10)):
    cfg = kl.KANNetConfig(layers=layers, G=G, P=P)
    params = kl.init_kan_net(jax.random.PRNGKey(seed), cfg)
    # noise=2.4 puts the task in the paper's mid-90s accuracy regime so the
    # int8 gap is actually stressed (noise=0.7 saturates at 100%)
    Xtr, Ytr = dp.mnist_like(8192, seed=1, noise=2.4)
    Xte, Yte = dp.mnist_like(2048, seed=2, noise=2.4)

    def loss_fn(p, xb, yb):
        logits = kl.kan_net_apply(p, xb, cfg)
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, yb[:, None], axis=-1).mean()

    @jax.jit
    def step(p, m, v, t, xb, yb):
        g = jax.grad(loss_fn)(p, xb, yb)
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.99 * a + 0.01 * b * b, v, g)
        p = jax.tree.map(
            lambda p_, m_, v_: p_ - lr * (m_ / 0.9999) / (jnp.sqrt(v_ / 0.9999) + 1e-8) * 1.0,
            p, m, v,
        )
        return p, m, v

    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    rs = np.random.RandomState(0)
    for t in range(steps):
        idx = rs.randint(0, len(Xtr), bs)
        params, m, v = step(params, m, v, t, jnp.asarray(Xtr[idx]), jnp.asarray(Ytr[idx]))
    return cfg, params, (Xte, Yte)


def accuracy_fp(cfg, params, X, Y):
    logits = kl.kan_net_apply(params, jnp.asarray(X), cfg)
    return float((jnp.argmax(logits, -1) == jnp.asarray(Y)).mean())


def accuracy_int8(cfg, params, X, Y):
    g = cfg.grid()
    qlayers = [q.quantize_kan_layer(p, g) for p in params]
    h = jnp.asarray(X)
    for i, ql in enumerate(qlayers):
        if i > 0:
            h = jnp.tanh(h)
        h = q.quantized_kan_forward(ql, h)
    return float((jnp.argmax(h, -1) == jnp.asarray(Y)).mean())


def run() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    cfg, params, (Xte, Yte) = train_mnist_kan()
    acc_fp = accuracy_fp(cfg, params, Xte, Yte)
    acc_q = accuracy_int8(cfg, params, Xte, Yte)
    us = (time.perf_counter() - t0) * 1e6
    drop = (acc_fp - acc_q) * 100
    return [
        (
            "quant.mnist_kan_synthetic",
            us,
            f"fp32_acc={acc_fp*100:.2f}%;int8_acc={acc_q*100:.2f}%;"
            f"drop={drop:.2f}pts;paper_drop=0.58pts;claim=<1pt;"
            f"pass={abs(drop) < 1.0}",
        )
    ]
