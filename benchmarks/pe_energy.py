"""Paper Table I: per-PE delay/power and *normalized energy*.

The delays/powers are the paper's published post-synthesis constants; the
normalized energy is OUR model's prediction (cycle model x Table-I power) —
matching the published row validates the (G+P)x cycle claim of §V-A."""

import time

from repro.core import sa_model as sm


def run() -> list[tuple[str, float, str]]:
    rows = []
    t0 = time.perf_counter()
    ok = True
    for (n, m), e_paper in sm.TABLE_I_NORM_ENERGY.items():
        e_model = sm.normalized_energy(n, m)
        ok &= abs(e_model - e_paper) < 0.011
        rows.append(
            (
                f"tableI.energy.{n}:{m}",
                0.0,
                f"model={e_model:.2f};paper={e_paper:.2f};"
                f"delay_ns={sm.pe_delay_ns(n,m):.2f};power_mw={sm.pe_power_mw(n,m):.2f}",
            )
        )
    us = (time.perf_counter() - t0) * 1e6 / len(sm.TABLE_I_NORM_ENERGY)
    rows.append(("tableI.all_match", us, f"match={ok}"))
    return rows
