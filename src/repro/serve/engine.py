"""Batched serving engine: prefill + decode with KV caches.

The engine jits one ``prefill`` per (batch, seq) bucket and ONE
scan-over-steps decode program per batch shape: the whole generation after
prefill is a single compiled ``jax.lax.scan`` (``max_new_tokens`` static),
so a request costs two XLA dispatches instead of ``max_new_tokens`` Python
round-trips.

Two serving drivers share that program:

* ``serve_requests`` — static bucketing: requests are length-sorted into
  fixed batches and each bucket drains to ``max_new_tokens`` (finished rows
  keep decoding into dead slots — the idle-PE problem in software);
* ``serve_continuous`` — true continuous batching: a slot table
  (``serve/scheduler.py``) runs fixed-shape jitted decode *chunks*
  (``chunk_steps``-long scans with per-row EOS latching) and swaps finished
  slots for queued requests between chunks via
  ``lm.prefill_into_slots`` — queued requests' KV is prefilled and spliced into
  a live batch cache row.  With ``ServeConfig.paged`` the dense per-slot
  cache rows become a block pool with per-request block tables, prefix
  caching, and preemption-with-recompute (DESIGN.md §3b) — same outputs,
  bit for bit.

Padding is **right**-padding with per-request start offsets: real tokens
sit at positions ``0..len-1``, causal attention means no real token ever
attends a pad, each request samples from the logits at its *own* last real
position, and decode starts ragged at ``pos_b = len_b`` (overwriting pad
cache slots before they become attendable).

Sampling is **per-row**: each row's PRNG key chain is derived from its
*request id* (``fold_in(PRNGKey(seed), request_id)``, then one split per
emitted token), never from its batch position — so even ``temperature >
0`` generation is bit-invariant to batch-mates, padding, and scheduling
(static vs continuous).  An earlier revision drew all rows' noise from one
batch-wide key, making sampled outputs depend on bucket composition.

EOS (``ServeConfig.eos_id >= 0``) latches per row: the EOS token itself is
emitted, every later step of that row emits ``pad_id`` and the row's
position freezes (its cache stops growing).  ``eos_id = -1`` (default)
never matches a real token id, so the same compiled program reproduces the
never-stop behavior exactly.  Under both greedy and sampled decoding a
request's full ``max_new``-token output (EOS, then pads) is bit-identical
between a solo ``generate`` call and any scheduling of
``serve_requests``/``serve_continuous`` (regression-tested).

Caveat: ragged decode into *windowed* (ring-buffer) attention layers can
still attend stale pad slots once a row's position wraps the window; the
KAN serving configs use full attention, where the invariance is exact.
SSM/LSTM block states are sequential and not pad-invariant under any
padding scheme; equal-length buckets avoid padding entirely.

Mesh-native serving (``ServeConfig.mesh``, DESIGN.md §4): the engine
places params and KV (dense rows or the paged block pool) on
``NamedSharding``s derived from their logical axes and threads a
``ShardingCtx`` through every jitted program, so decode chunks, slot
insertion, paged gather/writeback, and prefix-cache block copies stay
distributed across devices.  Host bookkeeping (scheduler, BlockPool,
PrefixCache) never sees device counts.  On one device the mesh path is
bit-identical to ``mesh=None``; across devices token outputs still match
(greedy and sampled) — only logits can differ in the last ulp, because
partitioned contractions reorder fp32 partial sums.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.retrace import RetraceRegistry, counting
from repro.models import lm
from repro.serve import speculative
from repro.serve.kv_pool import BlockPool, blocks_for, worst_case_blocks
from repro.serve.prefix_cache import PrefixCache
from repro.serve.scheduler import ContinuousScheduler


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 512
    max_new_tokens: int = 64
    temperature: float = 0.0
    eos_id: int = -1             # -1: never stops early
    pad_id: int = 0              # emitted after a row latches on EOS
    compute_dtype: str = "float32"
    decode_impl: str = "scan"    # "scan" (one compiled program) | "loop"
    # Paged KV cache (DESIGN.md §3b): carve the cache into fixed-size
    # blocks bound to requests on demand (serve/kv_pool.py), dedup shared
    # prompt prefixes (serve/prefix_cache.py), and preempt-with-recompute
    # on pool exhaustion.  Affects serve_continuous only; bit-identical to
    # the dense path.
    paged: bool = False
    block_size: int = 16         # must divide max_seq
    pool_blocks: int | None = None   # physical blocks incl. sentinel;
                                     # None -> slots·(max_seq/block_size)+1
                                     # (dense-equivalent capacity)
    prefix_caching: bool = True  # auto-disabled under int8 KV quant (the
                                 # dense path attends RAW prefill K/V;
                                 # reused blocks could only supply
                                 # dequantized values — bit-identity first)
    # Decode read path: "shadow" gathers the dense view ONCE per chunk,
    # runs the unchanged dense scan on it, and writes the chunk's span back
    # to the pools (gather amortized over chunk_steps; transient
    # slots x max_seq view).  "step" reads/writes through the block table
    # every token — the shape a fused TPU paged-attention kernel runs, and
    # the path with no transient view.  Both are bit-identical (tested).
    paged_read: str = "shadow"
    # Mesh-native serving (DESIGN.md §4): a jax.sharding.Mesh with
    # ("data", "model") axes (launch/mesh.py).  Parameters, dense cache
    # rows, and the paged block pool are placed on NamedShardings derived
    # from their logical axes (dist/sharding.py: kv_heads on "model",
    # slots/blocks on "data"), and every jitted serve program threads a
    # ShardingCtx so cache updates never silently gather to one device.
    # None (default) keeps the single-device engine — byte-for-byte the
    # pre-mesh behavior; a 1-device mesh compiles the same math and is
    # bit-identical to it.  All host-side bookkeeping (scheduler,
    # BlockPool, PrefixCache) is device-count-agnostic.
    mesh: object | None = None
    # Speculative decoding (DESIGN.md §9, serve/speculative.py): a shrunken
    # KAN drafter proposes spec_k tokens per window and ONE fused
    # verification pass scores all spec_k + 1 positions — batch-shaped work
    # that resolves to the fused kernel path instead of spec_k + 1 starved
    # single-token decode dispatches.  Outputs stay bit-identical to
    # spec_k = 0 (greedy AND temperature > 0): the verifier samples the
    # target chain at every window position with the request's own PRNG
    # chain and only ever emits those samples.  serve_continuous only.
    spec_k: int = 0              # drafts per window; 0 disables speculation
    draft: object | None = None  # a speculative.DraftModel; None derives one
                                 # from the target checkpoint at engine init
    draft_layers: int = 1        # derived drafter: leading unit repeats kept
    draft_quant: bool = False    # derived drafter: int8 fake-quant weights


class Engine:
    def __init__(self, params, model_cfg, serve_cfg: ServeConfig):
        self.model = model_cfg
        self.cfg = serve_cfg
        self._dt = jnp.float32 if serve_cfg.compute_dtype == "float32" else jnp.bfloat16
        self.last_serve_stats: dict | None = None
        self._last_pool = None      # paged-mode introspection (tests/bench)
        self._last_prefix = None

        # Mesh-native serving (ServeConfig.mesh): derive the parameter
        # shardings once, commit the params to them, and thread a
        # ShardingCtx through every jitted program below.  shard=None keeps
        # the single-device engine byte-identical to the pre-mesh code.
        if serve_cfg.mesh is not None:
            from repro.dist.sharding import ShardingCtx, shard_tree

            self.shard = ShardingCtx(serve_cfg.mesh)
            self._pshard = self.shard.param_shardings(model_cfg)
            params = shard_tree(params, self._pshard)
        else:
            self.shard = None
            self._pshard = None
        self.params = params
        self._cache_init_progs: dict = {}   # (kind, *shape) -> jitted init
        shard = self.shard
        # Retrace sentinel (repro.analysis.retrace): every jitted program
        # below is wrapped with counting() BEFORE jit, so each compilation
        # records (name, abstract signature).  The serving drivers export
        # the snapshot as last_serve_stats["compiles"], and the retrace
        # regression tests assert the documented budgets (one decode-chunk
        # program per chunk config, one prefill program per (group, bucket),
        # EOS sweeps add zero traces).
        self.compiles = RetraceRegistry()
        _count = lambda fn, name: counting(fn, name, self.compiles)  # noqa: E731

        def _jit(fn, *, param_argnum=None, **kw):
            """jit that pins the params argument to its sharding tree when a
            mesh is configured (in_shardings; other args stay inferred-from-
            commitment: None leaves = UNSPECIFIED).  Compiling the entry
            points with explicit in_shardings is what guarantees admission
            prefill never silently gathers the params to one device."""
            if shard is not None and param_argnum is not None:
                n_args = kw.pop("n_args")
                in_sh = [None] * n_args
                in_sh[param_argnum] = self._pshard
                kw["in_shardings"] = tuple(in_sh)
            else:
                kw.pop("n_args", None)
            return jax.jit(fn, **kw)

        self._prefill = _jit(
            _count(lambda p, inputs: lm.prefill(
                p, self.model, inputs, self.cfg.max_seq, self._dt, shard
            ), "prefill"),
            param_argnum=0, n_args=2,
        )
        self._decode = jax.jit(
            _count(lambda p, tok, caches, pos: lm.decode_step(
                p, self.model, tok, caches, pos, self._dt, None, shard
            ), "decode"),
            donate_argnums=(2,),   # caches update in place
        )
        # scan decode: the whole generation (or one continuous-batching
        # chunk) is one compiled program; retraces per static step count
        self._decode_scan = jax.jit(
            _count(self._scan_impl, "decode_chunk"),
            static_argnums=(0,), donate_argnums=(3,),
        )
        # continuous batching: prefill an admission *group* of k queued
        # requests in ONE dispatch and splice them into their slots
        # (retraces once per (k, padded prompt length) group shape — slots
        # free in bursts at chunk boundaries, so k-batching amortizes the
        # prefill dispatch overhead that dominates one-at-a-time refills)
        self._prefill_insert = _jit(
            _count(lambda p, toks, lengths, slots, caches:
                   lm.prefill_into_slots(
                       p, self.model, toks, lengths, slots, caches,
                       self.cfg.max_seq, self._dt, shard,
                   ), "prefill_insert"),
            param_argnum=0, n_args=5,
            donate_argnums=(4,),
        )
        # paged admission: suffix prefill scattered straight into pool
        # blocks.  view_blocks is STATIC (it truncates the attention view
        # to the causally reachable blocks — same flash sweep the dense
        # prefill does), so callers retrace per (group size, padded suffix
        # length, view blocks); the prefix start offset stays traced.
        self._prefill_pages = jax.jit(
            _count(lambda p, toks, lengths, tables, caches, start, view_blocks:
                   lm.prefill_into_pages(
                       p, self.model, toks, lengths, tables, caches, start,
                       self._dt, view_blocks, shard,
                   ), "prefill_pages"),
            donate_argnums=(4,), static_argnums=(6,),
        )
        # per-row key derivation + first-token sampling, shared by generate
        # and slot admission (jitted: the eager vmap path costs ms per call)
        self._keys_first = jax.jit(_count(self._keys_first_impl, "keys_first"))
        # paged "shadow" read path: per-chunk view gather + span writeback.
        # The gather's input pools are re-read by the writeback at the end
        # of the same chunk, so they must NOT be donated here:
        self._gather_views = jax.jit(   # kanlint: ignore[KL101]
            _count(lambda caches, table: lm.paged_views(caches, table, shard),
                   "gather_views")
        )
        # The view (argnum 1) is dead after its span is written back, but
        # its slot-shaped leaves (slots, max_seq, ...) can never alias the
        # pool-shaped outputs (n_blocks, bs, ...), so donating it buys
        # nothing and makes XLA warn about unusable donations every compile
        self._writeback_chunk = jax.jit(   # kanlint: ignore[KL101]
            _count(lambda caches, view, table, pos0, steps:
                   lm.writeback_paged_chunk(
                       caches, view, table, pos0, steps, shard),
                   "writeback_chunk"),
            static_argnums=(4,),
            donate_argnums=(0,),           # pools update in place
        )
        # ---- speculative decoding (DESIGN.md §9, serve/speculative.py) ----
        if serve_cfg.spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {serve_cfg.spec_k}")
        if serve_cfg.spec_k >= 1:
            self.draft = (
                serve_cfg.draft
                if serve_cfg.draft is not None
                else speculative.DraftModel.from_target(
                    self.params, model_cfg,
                    n_layers=serve_cfg.draft_layers,
                    quant=serve_cfg.draft_quant,
                )
            )
        else:
            self.draft = None
        # drafter weights are EXPLICIT jit arguments everywhere below —
        # closing over them would bake the whole drafter into each program
        # as XLA constants (re-staged per trace, resident per executable)
        self._draft_chunk = jax.jit(
            _count(self._draft_impl, "draft_chunk"),
            static_argnums=(0,), donate_argnums=(3,),
        )
        self._verify = _jit(
            _count(self._verify_impl, "verify_window"),
            param_argnum=0, n_args=10,
            donate_argnums=(3,),           # target caches update in place
        )
        # drafter admission prefill: the drafter keeps a dense per-slot
        # cache even under the paged target (its whole cache costs
        # draft_layers / n_repeats of ONE dense target cache), so admission
        # always prefills the FULL prompt into its row — prefix-cache hits
        # only skip target-side compute
        self._draft_prefill = jax.jit(
            _count(lambda p, toks, lengths, slots, draft_caches:
                   lm.prefill_into_slots(
                       p, self.draft.cfg, toks, lengths, slots, draft_caches,
                       self.cfg.max_seq, self._dt, shard,
                   ), "draft_prefill"),
            donate_argnums=(4,),
        )

    # ------------------------------------------------------------------
    # cache construction: on a mesh the trees are built under jit with
    # explicit out_shardings (dist/sharding.py derives them from the
    # cache_axes / paged_cache_axes trees), so the KV store is born
    # distributed; without a mesh this is the eager pre-mesh path.
    # ------------------------------------------------------------------

    def _make_dense_caches(self, slots: int):
        if self.shard is None:
            return lm.init_caches(self.model, slots, self.cfg.max_seq, self._dt)
        prog = self._cache_init_progs.get(("dense", slots))
        if prog is None:
            sh = self.shard.cache_shardings(
                self.model, slots, self.cfg.max_seq, self._dt
            )
            prog = jax.jit(
                counting(lambda: lm.init_caches(
                    self.model, slots, self.cfg.max_seq, self._dt
                ), "cache_init", self.compiles),
                out_shardings=sh,
            )
            self._cache_init_progs[("dense", slots)] = prog
        return prog()

    def _make_paged_caches(self, pool_blocks: int, block_size: int):
        if self.shard is None:
            return lm.init_paged_caches(
                self.model, pool_blocks, block_size, self._dt
            )
        key = ("paged", pool_blocks, block_size)
        prog = self._cache_init_progs.get(key)
        if prog is None:
            sh = self.shard.paged_cache_shardings(
                self.model, pool_blocks, block_size, self._dt
            )
            prog = jax.jit(
                counting(lambda: lm.init_paged_caches(
                    self.model, pool_blocks, block_size, self._dt
                ), "cache_init", self.compiles),
                out_shardings=sh,
            )
            self._cache_init_progs[key] = prog
        return prog()

    # ------------------------------------------------------------------
    # per-row PRNG: key chain = fold_in(base, request_id), split per token
    # ------------------------------------------------------------------

    @staticmethod
    def _row_key_pairs(base_key, request_ids: jax.Array) -> jax.Array:
        """(B,) request ids -> (B, 2, 2): [:, 0] the carried chain key,
        [:, 1] the first sampling key.  vmap of split == per-row split, so
        a solo call and any batched call agree bit-for-bit."""
        return jax.vmap(
            lambda r: jax.random.split(jax.random.fold_in(base_key, r))
        )(request_ids.astype(jnp.int32))

    def _keys_first_impl(self, base_key, request_ids, last_logits):
        """-> (carry keys (B, 2), first sampled token (B,)): each row's key
        chain and its first token, from the logits at its last real prompt
        position.  One definition serves solo ``generate`` and continuous
        slot admission, so the two are bit-identical by construction."""
        pairs = self._row_key_pairs(base_key, request_ids)
        return pairs[:, 0], self._sample(last_logits, pairs[:, 1])

    def _sample(self, logits: jax.Array, step_keys: jax.Array) -> jax.Array:
        """logits (B, vocab), step_keys (B, 2) — one key per row.  Delegates
        to the ONE sampling definition (speculative.sample_tokens) shared
        with the draft loop and the verifier, so the speculative acceptance
        rule compares like with like, bit for bit."""
        return speculative.sample_tokens(logits, step_keys,
                                         self.cfg.temperature)

    def _validate_request(self, rid, prompt_len: int, max_new: int) -> None:
        """Per-request admission validation (clear errors instead of a
        deep-in-trace assert): the prompt plus its token budget must fit
        the engine's ``max_seq``."""
        if max_new < 1:
            raise ValueError(
                f"request {rid}: max_new must be >= 1, got {max_new}"
            )
        if prompt_len < 1:
            raise ValueError(
                f"request {rid}: empty prompt (prompt_len={prompt_len})"
            )
        if prompt_len + max_new > self.cfg.max_seq:
            raise ValueError(
                f"request {rid}: prompt_len {prompt_len} + max_new {max_new} "
                f"= {prompt_len + max_new} exceeds max_seq {self.cfg.max_seq}"
            )

    def _scan_impl(self, steps, params, tok0, caches, pos0, keys0, eos_hit0,
                   eos_id, pad_id, table=None):
        """(steps static) scan body == one loop iteration of the unrolled
        decode, so scan and loop are bit-identical (tested).

        Per-row EOS latching: once row b emits ``eos_id`` every later step
        emits ``pad_id`` and (when ``pos`` is per-row) its position
        freezes.  ``eos_id``/``pad_id`` are traced scalars — one compiled
        program serves every eos choice, and ``eos_id = -1`` never matches
        a sampled token (ids are >= 0), reproducing never-stop exactly.
        Returns ``(toks (steps, B), tok_last, caches, pos, keys, eos_hit)``
        — the full carry, so continuous batching can resume the next chunk
        where this one left off.
        """

        def body(carry, _):
            tok, caches, pos, keys, eos_hit = carry
            lg, caches = lm.decode_step(
                params, self.model, tok, caches, pos, self._dt, table,
                self.shard,
            )
            pairs = jax.vmap(jax.random.split)(keys)
            keys, kt = pairs[:, 0], pairs[:, 1]
            nxt = self._sample(lg, kt)
            emitted = jnp.where(eos_hit, pad_id, nxt)
            eos_new = eos_hit | (nxt == eos_id)
            if pos.ndim == 0:      # synchronized scalar-position decode
                pos = pos + 1
            else:                  # ragged/continuous: latched rows freeze
                pos = jnp.where(eos_hit, pos, pos + 1)
            return (emitted[:, None], caches, pos, keys, eos_new), emitted

        (tok, caches, pos, keys, eos_hit), toks = jax.lax.scan(
            body, (tok0, caches, pos0, keys0, eos_hit0), None, length=steps
        )
        return toks, tok, caches, pos, keys, eos_hit   # toks: (steps, B)

    # ------------------------------------------------------------------
    # speculative decoding (DESIGN.md §9)
    # ------------------------------------------------------------------

    def _draft_impl(self, k, dparams, tok, draft_caches, pos, keys, eos_hit):
        """One draft window: ``k`` (static) cheap drafter decode steps
        proposing candidate tokens per live row, sampling with the same
        chain keys the verifier will replay against the target."""
        return speculative.draft_propose(
            dparams, self.draft.cfg, k, tok, draft_caches, pos, keys,
            eos_hit, self.cfg.temperature, self._dt, self.shard,
        )

    def _verify_impl(self, params, tok, draft, caches, pos, keys, eos_hit,
                     eos_id, pad_id, table=None):
        """Fused verification: score all ``W = k + 1`` window positions in
        ONE target forward (``lm.verify_window`` — B·W rows resolve to the
        fused kernel path), sample the target chain at every position with
        the request's own key chain, and accept the longest matching draft
        prefix plus the bonus token.  Returns ``(emitted (B, W), m (B,),
        tok', caches, pos', keys', eos')`` — the full decode carry advanced
        by exactly the ``m`` accepted emissions, bitwise the state the
        sequential chunk would carry after ``m`` steps."""
        B, k = draft.shape
        W = k + 1
        x = jnp.concatenate([tok, draft], axis=1)            # (B, W)
        logits, caches = lm.verify_window(
            params, self.model, x, caches, pos, self._dt, table, self.shard
        )
        kts, chains = speculative.split_chain(keys, W)
        t = speculative.sample_tokens(
            logits.reshape(B * W, -1), kts.reshape(B * W, 2),
            self.cfg.temperature,
        ).reshape(B, W)
        emitted, m, eos_new = speculative.accept_window(
            draft, t, eos_hit, eos_id, pad_id
        )
        # resume the chain after exactly m splits; tok' = last real emission
        keys_new = jnp.take_along_axis(chains, m[:, None, None], axis=1)[:, 0]
        last = jnp.take_along_axis(emitted, jnp.maximum(m - 1, 0)[:, None], 1)
        tok_new = jnp.where((m > 0)[:, None], last, tok)
        pos_new = pos + m
        return emitted, m, tok_new, caches, pos_new, keys_new, eos_new

    def generate(
        self,
        prompts: np.ndarray,
        seed: int = 0,
        lengths: np.ndarray | None = None,
        request_ids: np.ndarray | None = None,
        max_new: int | None = None,
        eos_id: int | None = None,
    ) -> np.ndarray:
        """prompts: (B, T_prompt) int32 -> (B, max_new) int32.

        ``lengths`` (optional, (B,)): true prompt lengths for right-padded
        prompts.  Each row then samples from the logits at its own last real
        token and decodes from its own start offset — generation is
        invariant to batch-mates and padding (module docstring).  Without
        ``lengths`` every row is taken as full-length (synchronized decode,
        collective-free scalar-position cache writes).

        ``request_ids`` (optional, (B,)): per-row sampling identity; rows
        with the same id draw the same noise in any batch (defaults to
        ``arange(B)``).  ``max_new``/``eos_id`` override the config values
        per call (``max_new`` retraces the scan; ``eos_id`` does not).
        Rows that emit ``eos_id`` latch: the output carries the EOS token
        followed by ``pad_id`` up to the fixed ``max_new`` length.
        """
        B, T = prompts.shape
        max_new = self.cfg.max_new_tokens if max_new is None else int(max_new)
        eos = self.cfg.eos_id if eos_id is None else int(eos_id)
        rids = (
            np.arange(B, dtype=np.int32)
            if request_ids is None
            else np.asarray(request_ids, np.int32)
        )
        assert rids.shape == (B,)
        # per-request validation (was a bare deep-in-trace assert): each
        # row's true prompt length + budget must fit max_seq
        row_lens = np.full((B,), T) if lengths is None else np.asarray(lengths)
        for b in range(B):
            self._validate_request(int(rids[b]), int(row_lens[b]), max_new)
        if T > self.cfg.max_seq:
            raise ValueError(
                f"padded prompt length {T} exceeds max_seq {self.cfg.max_seq}"
            )
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        if lengths is None:
            last = logits[:, T - 1]
            # synchronized decode (scalar position): collective-free writes
            # — unless EOS can latch rows at different steps, which needs
            # per-row frozen positions
            pos = (
                jnp.asarray(T, jnp.int32)
                if eos < 0
                else jnp.full((B,), T, jnp.int32)
            )
        else:
            lengths = np.asarray(lengths, np.int32)
            assert lengths.shape == (B,), (lengths.shape, B)
            assert lengths.min() >= 1 and lengths.max() <= T
            last = jnp.take_along_axis(
                logits, jnp.asarray(lengths - 1)[:, None, None], axis=1
            )[:, 0]
            # ragged decode: per-row start offsets; each row's first write
            # lands at slot len_b, overwriting the pad K/V before any mask
            # ever exposes it
            pos = jnp.asarray(lengths, jnp.int32)
        keys, tok0 = self._keys_first(
            jax.random.PRNGKey(seed), jnp.asarray(rids), last
        )
        tok = tok0[:, None]
        eos_hit = tok[:, 0] == eos          # eos = -1 never matches
        eos_a, pad_a = jnp.int32(eos), jnp.int32(self.cfg.pad_id)
        steps = max_new - 1
        if self.cfg.decode_impl == "scan":
            toks, _, _, _, _, _ = self._decode_scan(
                steps, self.params, tok, caches, pos, keys, eos_hit,
                eos_a, pad_a,
            )
            out = jnp.concatenate([tok, toks.T], axis=1)
        else:  # python-loop reference (one dispatch per step), mirrors body
            outs = [tok]
            for _ in range(steps):
                lg, caches = self._decode(self.params, tok, caches, pos)
                pairs = jax.vmap(jax.random.split)(keys)
                keys, kt = pairs[:, 0], pairs[:, 1]
                nxt = self._sample(lg, kt)
                emitted = jnp.where(eos_hit, pad_a, nxt)
                if pos.ndim == 0:
                    pos = pos + 1
                else:
                    pos = jnp.where(eos_hit, pos, pos + 1)
                eos_hit = eos_hit | (nxt == eos_a)
                tok = emitted[:, None]
                outs.append(tok)
            out = jnp.concatenate(outs, axis=1)
        return np.asarray(out)

    def serve_requests(
        self, requests: list[np.ndarray], batch_size: int = 8, seed: int = 0
    ) -> list[np.ndarray]:
        """Bucket requests BY LENGTH into fixed batches (pad with copies) and
        drain bucket by bucket — the *static* batched-serving driver.
        Length-sorting means each bucket pads to its own max prompt length,
        not the global max.  Mixed-length buckets RIGHT-pad and thread the
        true lengths through ``generate``; per-row sampling keys are derived
        from each request's index in ``requests``, so outputs (greedy OR
        sampled) never depend on batch-mates or padding.  Finished (EOS)
        rows latch but their slots are NOT recycled — see
        :meth:`serve_continuous` for that."""
        order = sorted(range(len(requests)), key=lambda i: requests[i].shape[0])
        results: list[np.ndarray | None] = [None] * len(requests)
        t0 = time.perf_counter()
        buckets: list[dict] = []
        for start in range(0, len(order), batch_size):
            idxs = order[start : start + batch_size]
            bucket = [requests[i] for i in idxs]
            T = max(r.shape[0] for r in bucket)
            lens = np.asarray([r.shape[0] for r in bucket], np.int32)
            rids = np.asarray(idxs, np.int32)
            padded = np.stack(
                [np.pad(r, (0, T - r.shape[0]), constant_values=0) for r in bucket]
            )
            while padded.shape[0] < batch_size:
                padded = np.concatenate([padded, padded[-1:]], axis=0)
                lens = np.concatenate([lens, lens[-1:]], axis=0)
                rids = np.concatenate([rids, rids[-1:]], axis=0)
            gen = self.generate(
                padded.astype(np.int32), seed=seed,
                lengths=None if bool((lens == T).all()) else lens,
                request_ids=rids,
            )
            for j, i in enumerate(idxs):
                results[i] = gen[j]
            # a request "completes" when its bucket drains — the latency
            # accounting the serving benchmark compares against continuous
            buckets.append({
                "request_ids": idxs,
                "rows": int(padded.shape[0]),
                "done_s": time.perf_counter() - t0,
            })
        self.last_serve_stats = {
            "wall_s": time.perf_counter() - t0,
            "buckets": buckets,
            "request_latency_s": [
                next(b["done_s"] for b in buckets if i in b["request_ids"])
                for i in range(len(requests))
            ],
            "compiles": self.compiles.snapshot(),
        }
        return results  # type: ignore[return-value]

    # ------------------------------------------------------------------
    # continuous batching
    # ------------------------------------------------------------------

    def serve_continuous(
        self,
        requests: list[np.ndarray],
        slots: int = 8,
        chunk_steps: int = 8,
        seed: int = 0,
        max_new: int | list[int] | None = None,
        prompt_pad_multiple: int = 8,
    ) -> list[np.ndarray]:
        """True continuous batching: a ``slots``-row decode batch whose rows
        are recycled the moment a request finishes (EOS latch or token
        budget), instead of draining with the bucket.

        The loop alternates two fixed-shape jitted programs: a decode
        *chunk* (``chunk_steps`` scan steps over all slots, per-row EOS
        latching/frozen positions for dead rows) and ``lm.prefill_into_slots``
        (one queued request prefilled at a bucketed prompt length and its
        KV spliced into the freed row).  Between chunks the host scheduler
        (``serve/scheduler.py``) retires finished slots and admits from the
        FIFO queue.  Recompile boundaries: one trace per ``chunk_steps``
        value and one per padded prompt length (``prompt_pad_multiple``
        buckets them).

        ``max_new``: per-request (list) or global token budgets; default
        ``cfg.max_new_tokens``.  Each request's output has exactly its
        budget's length, padded with ``pad_id`` after EOS — bit-identical
        to a solo :meth:`generate` call with the same ``request_id`` (its
        index in ``requests``), for greedy AND sampled decoding.

        With ``cfg.paged`` (DESIGN.md §3b) the KV cache is a block pool
        (``serve/kv_pool.py``) instead of dense per-slot rows: admission is
        allocation-aware (a request only enters a slot when its prompt's
        blocks are available), shared prompt prefixes reuse cached blocks
        (``serve/prefix_cache.py`` — prefill computes only the uncached
        suffix), and pool exhaustion first evicts LRU prefix entries, then
        preempts the youngest running request (freed blocks, requeued at
        the queue head, restarted from scratch on re-admission —
        recompute regenerates the identical token stream).  All of it holds
        the same contract: outputs stay bit-identical to solo
        :meth:`generate`.

        Sets ``self.last_serve_stats`` (scheduler counters incl.
        ``n_preemptions``, per-request latency, wall time; paged mode adds
        a ``"paged"`` sub-dict with pool/prefix counters and
        prefill-tokens-saved) for the serving benchmarks.
        """
        n = len(requests)
        if max_new is None:
            budgets = [self.cfg.max_new_tokens] * n
        elif isinstance(max_new, int):
            budgets = [max_new] * n
        else:
            budgets = [int(m) for m in max_new]
            assert len(budgets) == n
        eos, pad = self.cfg.eos_id, self.cfg.pad_id
        for rid, (r, m) in enumerate(zip(requests, budgets)):
            self._validate_request(rid, int(r.shape[0]), m)
        assert chunk_steps >= 1 and slots >= 1
        spec_k = self.cfg.spec_k
        spec = spec_k >= 1
        W = spec_k + 1              # verify-window width (drafts + bonus)
        # device write span per decode round: a spec window writes
        # pos..pos+W-1 (tok + k drafts), a plain chunk pos..pos+chunk_steps-1
        steps_cov = W if spec else chunk_steps

        sched = ContinuousScheduler(slots, range(n))
        paged = self.cfg.paged
        if paged:
            if self.cfg.paged_read not in ("shadow", "step"):
                raise ValueError(
                    f"paged_read must be 'shadow' or 'step', "
                    f"got {self.cfg.paged_read!r}"
                )
            bs_blk = self.cfg.block_size
            if bs_blk < 1 or self.cfg.max_seq % bs_blk:
                raise ValueError(
                    f"block_size {bs_blk} must divide max_seq {self.cfg.max_seq}"
                )
            n_logical = self.cfg.max_seq // bs_blk
            pool_blocks = self.cfg.pool_blocks
            if pool_blocks is None:
                # default: dense-equivalent capacity (+ the sentinel)
                pool_blocks = slots * n_logical + 1
            if pool_blocks < 2:
                raise ValueError(
                    f"pool_blocks must be >= 2 (the reserved sentinel plus "
                    f"at least one usable block), got {pool_blocks}"
                )
            pool = BlockPool(pool_blocks, bs_blk)
            # paged admission validation: any single request must fit an
            # otherwise-empty pool, so preemption can always make progress
            for rid, (r, m) in enumerate(zip(requests, budgets)):
                need = worst_case_blocks(
                    int(r.shape[0]), m, chunk_steps, bs_blk, self.cfg.max_seq,
                    spec_k=spec_k,
                )
                if need > pool.usable:
                    raise ValueError(
                        f"request {rid}: worst-case footprint {need} blocks "
                        f"(prompt {r.shape[0]} + max_new {m}, block_size "
                        f"{bs_blk}) exceeds the pool's {pool.usable} usable "
                        f"blocks — raise pool_blocks or shrink the request"
                    )
            kv_quant = lm.model_kv_quant(self.model)
            # prefix reuse is OFF under int8 KV quant (ServeConfig note)
            prefix = (
                PrefixCache(bs_blk)
                if self.cfg.prefix_caching and not kv_quant else None
            )
            caches = self._make_paged_caches(pool_blocks, bs_blk)
            tables = np.zeros((slots, n_logical), np.int32)  # 0 == sentinel
            tables_dev = {"arr": None, "dirty": True}  # upload-once per change
            covered = np.zeros((slots,), np.int64)     # blocks bound per slot
            slot_rid = np.full((slots,), -1, np.int64)
            prefill_tok = {"computed": 0, "saved": 0}
            key_chains: dict[int, list] = {}   # rid -> immutable hash chain
                                               # (deferred admissions re-probe
                                               # without re-hashing)
        else:
            prefix = None
            caches = self._make_dense_caches(slots)
        # drafter KV: always dense per-slot rows, draft_layers deep — its
        # whole footprint is draft_layers / n_repeats of ONE dense target
        # cache, the HBM cost of speculation (DESIGN.md §9)
        draft_caches = (
            lm.init_caches(self.draft.cfg, slots, self.cfg.max_seq, self._dt)
            if spec else None
        )
        spec_stats = {"windows": 0, "proposed": 0, "accepted": 0, "emitted": 0}
        # host mirrors of the per-slot device state fed to each chunk
        tok = np.zeros((slots, 1), np.int32)
        pos = np.zeros((slots,), np.int32)
        keys = np.zeros((slots, 2), np.uint32)
        eos_hit = np.ones((slots,), bool)      # empty slots stay latched
        base = jax.random.PRNGKey(seed)
        bufs: list[list[int]] = [[] for _ in range(n)]
        outputs: list[np.ndarray | None] = [None] * n
        t0 = time.perf_counter()
        latency = [0.0] * n

        def finalize(rid: int) -> None:
            got = bufs[rid][: budgets[rid]]
            out = np.full((budgets[rid],), pad, np.int32)
            out[: len(got)] = got
            outputs[rid] = out
            latency[rid] = time.perf_counter() - t0

        def activate_group(pairs, lens, last):
            """Shared admission tail (dense AND paged): derive per-request
            key chains + first tokens from the prefill logits, then either
            activate each slot or retire it on the spot (budget-1 request,
            or the very first token hit EOS).  One definition keeps the two
            admission paths in bitwise lockstep."""
            rids_a = jnp.asarray(np.asarray([rid for _, rid in pairs], np.int32))
            # one batched device->host transfer for both results (two bare
            # np.asarray calls here were two serial syncs — kanlint KL102)
            kcs, firsts = jax.device_get(self._keys_first(base, rids_a, last))
            for j, (b, rid) in enumerate(pairs):
                first = int(firsts[j])
                bufs[rid].append(first)
                hit = eos >= 0 and first == eos
                if sched.confirm_admit(b, rid, int(lens[j]),
                                       budgets[rid] - 1, hit):
                    finalize(rid)       # done at admission: the freed slot
                    sched.retire(b)     # is refilled by the next round
                    if paged:
                        release_slot_blocks(b)
                    eos_hit[b] = True
                else:
                    tok[b, 0] = first
                    pos[b] = int(lens[j])
                    keys[b] = kcs[j]
                    eos_hit[b] = False

        def admit_all():
            nonlocal caches, draft_caches
            while True:
                ready = sched.admit_ready()
                if not ready:
                    return
                # one prefill dispatch per (padded length) admission group
                groups: dict[int, list[tuple[int, int]]] = {}
                for b, rid in ready:
                    L = requests[rid].shape[0]
                    # clamp: padding past L is causally invisible, but the
                    # prefilled cache must still fit the (slots, max_seq)
                    # live cache it is spliced into
                    t_pad = min(
                        -(-L // prompt_pad_multiple) * prompt_pad_multiple,
                        self.cfg.max_seq,
                    )
                    groups.setdefault(t_pad, []).append((b, rid))
                for t_pad, grp in sorted(groups.items()):
                    slots_a = np.asarray([b for b, _ in grp], np.int32)
                    lens = np.asarray(
                        [requests[rid].shape[0] for _, rid in grp], np.int32
                    )
                    padded = np.stack([
                        np.pad(requests[rid], (0, t_pad - requests[rid].shape[0]))
                        for _, rid in grp
                    ]).astype(np.int32)
                    last, caches = self._prefill_insert(
                        self.params, padded, lens, slots_a, caches
                    )
                    if spec:
                        # drafter cache row enters lockstep here: admission
                        # overwrites the whole row, so slot recycling and
                        # preemption-with-recompute can never leak a prior
                        # occupant's drafter KV into a new request
                        _, draft_caches = self._draft_prefill(
                            self.draft.params, padded, lens, slots_a,
                            draft_caches,
                        )
                    activate_group(grp, lens, last)

        # ---------------------- paged-mode machinery ----------------------

        def release_slot_blocks(b: int) -> None:
            """Drop slot b's block bindings (retire or preempt): the pool
            drops the request's refs (prefix-cache-held blocks survive) and
            the table row resets to the sentinel so the fixed-shape chunk's
            writes for this dead row land in the trash block."""
            pool.release_request(int(slot_rid[b]))
            tables[b, :] = 0
            tables_dev["dirty"] = True
            covered[b] = 0
            slot_rid[b] = -1

        def free_up(need: int, protect_slot: int | None) -> bool:
            """Make ``need`` blocks free: first evict LRU prefix-cache
            entries, then preempt the youngest live request (requeued at
            the queue head; its re-run regenerates the same tokens —
            preemption-with-recompute).  Returns False once ``protect_slot``
            itself was preempted (the caller stops extending it)."""
            while pool.free_count() < need:
                if prefix is not None and prefix.evict_lru(pool) is not None:
                    continue
                victim = sched.youngest_live_slot()
                assert victim is not None, "pool exhausted with no live rows"
                rid_v = sched.preempt(victim)
                bufs[rid_v] = []          # restart from scratch on re-admit
                release_slot_blocks(victim)
                eos_hit[victim] = True
                if victim == protect_slot:
                    return False
            return True

        def admit_all_paged():
            nonlocal caches, draft_caches
            while True:
                ready = sched.admit_ready()
                if not ready:
                    return
                # bind blocks per request; group dispatches by
                # (prefix start, padded suffix length)
                groups: dict[tuple[int, int], list] = {}
                deferred: list[int] = []
                for b, rid in ready:
                    toks_r = requests[rid]
                    L = toks_r.shape[0]
                    if prefix is not None:
                        n_hit, hit_blocks, keys_r = prefix.match(
                            toks_r, key_chains.get(rid)
                        )
                        key_chains[rid] = keys_r
                    else:
                        n_hit, hit_blocks, keys_r = 0, [], []
                    start = n_hit * bs_blk
                    n_new = blocks_for(L, bs_blk) - n_hit
                    # share FIRST: a matched cache-only block must not be
                    # evicted while we free room for the fresh suffix blocks
                    pool.share(rid, hit_blocks)
                    ok = pool.free_count() >= n_new
                    while not ok and prefix is not None:
                        if prefix.evict_lru(pool) is None:
                            break
                        ok = pool.free_count() >= n_new
                    if not ok:
                        # admission never preempts (that would thrash);
                        # blocks free as running requests retire
                        pool.release_request(rid)
                        deferred.append(rid)
                        continue
                    row = hit_blocks + pool.alloc(rid, n_new)
                    if prefix is not None:
                        prefix.record_admission(n_hit, L)
                    tables[b, :] = 0
                    tables[b, : len(row)] = row
                    tables_dev["dirty"] = True
                    covered[b] = len(row)
                    slot_rid[b] = rid
                    prefill_tok["saved"] += start
                    prefill_tok["computed"] += L - start
                    t_pad = min(
                        -(-(L - start) // prompt_pad_multiple) * prompt_pad_multiple,
                        self.cfg.max_seq - start,
                    )
                    groups.setdefault((start, t_pad), []).append(
                        (b, rid, L, keys_r)
                    )
                for (start, t_pad), grp in sorted(groups.items()):
                    lens = np.asarray([L for _, _, L, _ in grp], np.int32)
                    suffix = np.stack([
                        np.pad(requests[rid][start:], (0, t_pad - (L - start)))
                        for _, rid, L, _ in grp
                    ]).astype(np.int32)
                    tbls = jnp.asarray(tables[[b for b, *_ in grp]])
                    last, caches = self._prefill_pages(
                        self.params, suffix, jnp.asarray(lens), tbls, caches,
                        jnp.int32(start), blocks_for(start + t_pad, bs_blk),
                    )
                    if spec:
                        # the drafter has no paged pool and no prefix cache:
                        # prefill its dense row with the FULL prompt (target
                        # prefix hits only skip target-side compute).
                        # start + t_pad is group-constant, so one dispatch
                        full = np.stack([
                            np.pad(requests[rid], (0, start + t_pad - L))
                            for _, rid, L, _ in grp
                        ]).astype(np.int32)
                        slots_a = np.asarray([b for b, *_ in grp], np.int32)
                        _, draft_caches = self._draft_prefill(
                            self.draft.params, full, lens, slots_a,
                            draft_caches,
                        )
                    # register the freshly computed full prompt blocks so
                    # later admissions can reuse them (first writer wins)
                    if prefix is not None:
                        for b, rid, L, keys_r in grp:
                            for i, key in enumerate(keys_r):
                                if prefix.insert(key, int(tables[b, i])):
                                    pool.cache_ref(int(tables[b, i]))
                    activate_group([(b, rid) for b, rid, _, _ in grp],
                                   lens, last)
                if deferred:
                    # head-of-queue, original order: they re-admit first
                    for rid in reversed(deferred):
                        sched.queue.push_front(rid)
                    return

        def ensure_chunk_coverage():
            """Before a chunk, every live row's table must cover the full
            ``chunk_steps`` of writes (fixed-shape scans advance positions
            regardless of remaining budget; writes past ``max_seq`` are
            sentinel-redirected device-side).  Pool exhaustion here is what
            triggers eviction / preempt-youngest."""
            for b in list(sched.table.live_slots()):
                s = sched.table.slots[b]
                if not s.occupied or s.eos_hit:
                    continue   # preempted/retired meanwhile
                want = blocks_for(
                    min(int(pos[b]) + steps_cov, self.cfg.max_seq), bs_blk
                )
                need = int(want - covered[b])
                if need <= 0:
                    continue
                if not free_up(need, protect_slot=b):
                    continue   # b itself was preempted
                fresh = pool.alloc(int(slot_rid[b]), need)
                tables[b, int(covered[b]): int(covered[b]) + need] = fresh
                tables_dev["dirty"] = True
                covered[b] += need

        eos_a, pad_a = jnp.int32(eos), jnp.int32(pad)
        while True:
            admit_all_paged() if paged else admit_all()
            sched.check_invariants()
            if paged:
                ensure_chunk_coverage()
            if not sched.can_run_chunk():
                if paged and sched.has_work():
                    continue   # everything preempted: re-admit and retry
                break
            if paged and tables_dev["dirty"]:
                tables_dev["arr"] = jnp.asarray(tables)
                tables_dev["dirty"] = False
            if spec:
                # one window: k drafter steps, then ONE fused verify pass
                pos0 = jnp.asarray(pos)
                tok0 = jnp.asarray(tok)
                keys0 = jnp.asarray(keys)
                eos0 = jnp.asarray(eos_hit)
                draft, draft_caches = self._draft_chunk(
                    spec_k, self.draft.params, tok0, draft_caches, pos0,
                    keys0, eos0,
                )
                if paged and self.cfg.paged_read == "shadow":
                    view = self._gather_views(caches, tables_dev["arr"])
                    emitted_d, m_d, tok_l, view, pos_l, keys_l, eos_l = (
                        self._verify(
                            self.params, tok0, draft, view, pos0, keys0,
                            eos0, eos_a, pad_a, None,
                        )
                    )
                    caches = self._writeback_chunk(
                        caches, view, tables_dev["arr"], pos0, W
                    )
                else:
                    emitted_d, m_d, tok_l, caches, pos_l, keys_l, eos_l = (
                        self._verify(
                            self.params, tok0, draft, caches, pos0, keys0,
                            eos0, eos_a, pad_a,
                            tables_dev["arr"] if paged else None,
                        )
                    )
                emitted_h, m_h, tok, pos, keys, eos_hit = [
                    np.array(a) for a in jax.device_get(
                        (emitted_d, m_d, tok_l, pos_l, keys_l, eos_l)
                    )
                ]
                # emitted rows carry no post-EOS pads inside m (accept_window
                # truncates at EOS), so useful == n_keep — no eos_steps pass
                spec_stats["windows"] += 1
                for b, rid, n_keep, finished in sched.complete_spec_window(
                    W, m_h, eos_hit
                ):
                    spec_stats["proposed"] += spec_k
                    spec_stats["accepted"] += max(0, min(int(m_h[b]) - 1,
                                                         spec_k))
                    spec_stats["emitted"] += n_keep
                    bufs[rid].extend(int(t) for t in emitted_h[b, :n_keep])
                    if finished:
                        finalize(rid)
                        sched.retire(b)
                        if paged:
                            release_slot_blocks(b)
                        eos_hit[b] = True
                if paged:
                    # roll back rejected coverage: blocks past the accepted
                    # frontier are request-exclusive FRESH blocks (admission
                    # caps prefix reuse below blocks_for(pos')), so the trim
                    # frees them outright — no CoW, no device copy
                    for b in sched.table.live_slots():
                        keep = blocks_for(int(pos[b]), bs_blk)
                        if keep < covered[b]:
                            pool.trim_request(int(slot_rid[b]), keep)
                            tables[b, keep:] = 0
                            tables_dev["dirty"] = True
                            covered[b] = keep
                continue
            if paged and self.cfg.paged_read == "shadow":
                # gather once per chunk, dense-scan the view, write the
                # chunk's span back — per-step decode cost equals dense
                pos0 = jnp.asarray(pos)
                view = self._gather_views(caches, tables_dev["arr"])
                toks, tok_l, view, pos_l, keys_l, eos_l = self._decode_scan(
                    chunk_steps, self.params, jnp.asarray(tok), view,
                    pos0, jnp.asarray(keys), jnp.asarray(eos_hit),
                    eos_a, pad_a, None,
                )
                caches = self._writeback_chunk(
                    caches, view, tables_dev["arr"], pos0, chunk_steps
                )
            else:
                toks, tok_l, caches, pos_l, keys_l, eos_l = self._decode_scan(
                    chunk_steps, self.params, jnp.asarray(tok), caches,
                    jnp.asarray(pos), jnp.asarray(keys), jnp.asarray(eos_hit),
                    eos_a, pad_a, tables_dev["arr"] if paged else None,
                )
            # one device->host transfer; np.array copies because the host
            # mirrors are written by admission/retirement below
            toks, tok, pos, keys, eos_hit = [
                np.array(a)
                for a in jax.device_get((toks, tok_l, pos_l, keys_l, eos_l))
            ]
            if eos >= 0:
                # first in-chunk EOS emission per slot (chunk_steps if
                # none): post-EOS pads count as waste in the utilization
                hits = toks == eos
                eos_steps = np.where(
                    hits.any(axis=0), hits.argmax(axis=0), chunk_steps
                )
            else:
                eos_steps = None
            for b, rid, n_keep, finished in sched.complete_chunk(
                chunk_steps, eos_hit, eos_steps
            ):
                bufs[rid].extend(int(t) for t in toks[:n_keep, b])
                if finished:
                    finalize(rid)
                    sched.retire(b)
                    if paged:
                        release_slot_blocks(b)
                    eos_hit[b] = True

        sched.check_invariants()
        assert all(o is not None for o in outputs)
        self.last_serve_stats = {
            **sched.stats(),
            "wall_s": time.perf_counter() - t0,
            "request_latency_s": latency,
            "useful_tokens": int(sum(budget_used(bufs[i], budgets[i], eos)
                                     for i in range(n))),
            "mesh_shape": dict(self.shard.mesh.shape) if self.shard else None,
            "compiles": self.compiles.snapshot(),
        }
        if spec:
            self.last_serve_stats["spec"] = {
                "spec_k": spec_k,
                "draft_layers": self.draft.n_layers,
                "draft_quant": self.draft.quant,
                "windows": spec_stats["windows"],
                "proposed_drafts": spec_stats["proposed"],
                "accepted_drafts": spec_stats["accepted"],
                "acceptance_rate": (
                    spec_stats["accepted"] / max(spec_stats["proposed"], 1)
                ),
                "emitted_tokens": spec_stats["emitted"],
            }
        if paged:
            # after drain every block is free or prefix-cache-held (rc 1):
            # leaked blocks / unbalanced refcounts fail loudly here, and the
            # equivalence battery asserts the exported counters besides
            pool.check_balanced(n_live_requests=0)
            self.last_serve_stats["paged"] = {
                **pool.stats(),
                **(prefix.stats() if prefix is not None else
                   {"prefix_caching": False}),
                "prefill_tokens_computed": prefill_tok["computed"],
                "prefill_tokens_saved": prefill_tok["saved"],
            }
            self._last_pool = pool          # test introspection handles
            self._last_prefix = prefix
        return outputs  # type: ignore[return-value]


def budget_used(buf: list[int], budget: int, eos: int) -> int:
    """Tokens a request actually *used*: up to and including its EOS, else
    its full budget (serving-benchmark accounting)."""
    toks = buf[:budget]
    if eos >= 0 and eos in toks:
        return toks.index(eos) + 1
    return len(toks)
