"""Batched serving engine: prefill + decode with KV caches.

The engine jits one ``prefill`` and one ``decode_step`` per (batch, seq)
bucket and runs greedy/temperature sampling. Continuous batching is modelled
with per-slot positions: finished sequences keep decoding into a dead slot
until the batch drains (the standard static-batch serving compromise; true
continuous batching needs host-side slot swapping, which `serve_requests`
implements at bucket granularity)."""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 512
    max_new_tokens: int = 64
    temperature: float = 0.0
    eos_id: int = -1             # -1: never stops early
    compute_dtype: str = "float32"


class Engine:
    def __init__(self, params, model_cfg, serve_cfg: ServeConfig):
        self.params = params
        self.model = model_cfg
        self.cfg = serve_cfg
        dt = jnp.dtype(serve_cfg.compute_dtype).type
        self._dt = jnp.float32 if serve_cfg.compute_dtype == "float32" else jnp.bfloat16

        self._prefill = jax.jit(
            lambda p, inputs: lm.prefill(
                p, self.model, inputs, self.cfg.max_seq, self._dt
            )
        )
        self._decode = jax.jit(
            lambda p, tok, caches, pos: lm.decode_step(
                p, self.model, tok, caches, pos, self._dt
            ),
            donate_argnums=(2,),   # caches update in place
        )

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.cfg.temperature).astype(
            jnp.int32
        )

    def generate(self, prompts: np.ndarray, seed: int = 0) -> np.ndarray:
        """prompts: (B, T_prompt) int32 -> (B, max_new_tokens) int32."""
        B, T = prompts.shape
        assert T + self.cfg.max_new_tokens <= self.cfg.max_seq
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        key = jax.random.PRNGKey(seed)
        key, k0 = jax.random.split(key)
        tok = self._sample(logits[:, T - 1], k0)[:, None]
        out = [tok]
        # synchronized decode (scalar position): collective-free cache writes
        pos = jnp.asarray(T, jnp.int32)
        for _ in range(self.cfg.max_new_tokens - 1):
            lg, caches = self._decode(self.params, tok, caches, pos)
            key, kt = jax.random.split(key)
            tok = self._sample(lg, kt)[:, None]
            out.append(tok)
            pos = pos + 1
        return np.asarray(jnp.concatenate(out, axis=1))

    def serve_requests(
        self, requests: list[np.ndarray], batch_size: int = 8, seed: int = 0
    ) -> list[np.ndarray]:
        """Bucket requests to a fixed batch (pad with copies), drain bucket
        by bucket — the batched-serving driver used by examples/serve_kan.py."""
        results: list[np.ndarray] = []
        for i in range(0, len(requests), batch_size):
            bucket = requests[i : i + batch_size]
            T = max(r.shape[0] for r in bucket)
            padded = np.stack(
                [np.pad(r, (T - r.shape[0], 0), constant_values=0) for r in bucket]
            )
            while padded.shape[0] < batch_size:
                padded = np.concatenate([padded, padded[-1:]], axis=0)
            gen = self.generate(padded.astype(np.int32), seed=seed + i)
            results.extend(gen[: len(bucket)])
        return results
