"""Batched serving engine: prefill + decode with KV caches.

The engine jits one ``prefill`` per (batch, seq) bucket and ONE
scan-over-steps decode program per batch shape: the whole generation after
prefill is a single compiled ``jax.lax.scan`` (``max_new_tokens`` static),
so a request costs two XLA dispatches instead of ``max_new_tokens`` Python
round-trips.  Continuous batching is modelled with per-slot positions:
finished sequences keep decoding into a dead slot until the batch drains
(the standard static-batch serving compromise; true continuous batching
needs host-side slot swapping, which ``serve_requests`` implements at
bucket granularity).

``serve_requests`` buckets requests by prompt length before batching, so a
mixed-length request list pads each bucket to its own max instead of the
global max (DESIGN.md §3).

Padding is **right**-padding with per-request start offsets: real tokens
sit at positions ``0..len-1``, causal attention means no real token ever
attends a pad, each request samples from the logits at its *own* last real
position, and decode starts ragged at ``pos_b = len_b`` (overwriting pad
cache slots before they become attendable).  Under greedy decoding
(``temperature == 0``, the default) a request's generation is therefore
invariant to its batch-mates and to the amount of padding
(regression-tested); with ``temperature > 0`` the *logits* are still
pad-invariant, but the sampling noise is drawn from one PRNG key over the
whole batch, so sampled tokens depend on bucket composition.  The previous
revision left-padded and attended the pads unmasked — even the logits
changed with bucket composition.  Caveat: ragged
decode into *windowed* (ring-buffer) attention layers can still attend
stale pad slots once a row's position wraps the window; the KAN serving
configs use full attention, where the invariance is exact.  SSM/LSTM block
states are sequential and not pad-invariant under any padding scheme;
equal-length buckets (the common case after length bucketing) avoid
padding entirely.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 512
    max_new_tokens: int = 64
    temperature: float = 0.0
    eos_id: int = -1             # -1: never stops early
    compute_dtype: str = "float32"
    decode_impl: str = "scan"    # "scan" (one compiled program) | "loop"


class Engine:
    def __init__(self, params, model_cfg, serve_cfg: ServeConfig):
        self.params = params
        self.model = model_cfg
        self.cfg = serve_cfg
        self._dt = jnp.float32 if serve_cfg.compute_dtype == "float32" else jnp.bfloat16

        self._prefill = jax.jit(
            lambda p, inputs: lm.prefill(
                p, self.model, inputs, self.cfg.max_seq, self._dt
            )
        )
        self._decode = jax.jit(
            lambda p, tok, caches, pos: lm.decode_step(
                p, self.model, tok, caches, pos, self._dt
            ),
            donate_argnums=(2,),   # caches update in place
        )
        # scan decode: the whole generation is one compiled program
        self._decode_scan = jax.jit(
            self._scan_impl, static_argnums=(0,), donate_argnums=(3,)
        )

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.cfg.temperature).astype(
            jnp.int32
        )

    def _scan_impl(self, steps, params, tok0, caches, pos0, key0):
        """(steps static) scan body == one loop iteration of the unrolled
        decode, so scan and loop are bit-identical (tested)."""

        def body(carry, _):
            tok, caches, pos, key = carry
            lg, caches = lm.decode_step(
                params, self.model, tok, caches, pos, self._dt
            )
            key, kt = jax.random.split(key)
            nxt = self._sample(lg, kt)[:, None]
            return (nxt, caches, pos + 1, key), nxt[:, 0]

        (_, caches, _, _), toks = jax.lax.scan(
            body, (tok0, caches, pos0, key0), None, length=steps
        )
        return toks, caches   # toks: (steps, B)

    def generate(
        self,
        prompts: np.ndarray,
        seed: int = 0,
        lengths: np.ndarray | None = None,
    ) -> np.ndarray:
        """prompts: (B, T_prompt) int32 -> (B, max_new_tokens) int32.

        ``lengths`` (optional, (B,)): true prompt lengths for right-padded
        prompts.  Each row then samples from the logits at its own last real
        token and decodes from its own start offset — generation is
        invariant to batch-mates and padding (module docstring).  Without
        ``lengths`` every row is taken as full-length (synchronized decode,
        collective-free scalar-position cache writes).
        """
        B, T = prompts.shape
        assert T + self.cfg.max_new_tokens <= self.cfg.max_seq
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        key = jax.random.PRNGKey(seed)
        key, k0 = jax.random.split(key)
        if lengths is None:
            last = logits[:, T - 1]
            # synchronized decode (scalar position): collective-free writes
            pos = jnp.asarray(T, jnp.int32)
        else:
            lengths = np.asarray(lengths, np.int32)
            assert lengths.shape == (B,), (lengths.shape, B)
            assert lengths.min() >= 1 and lengths.max() <= T
            last = jnp.take_along_axis(
                logits, jnp.asarray(lengths - 1)[:, None, None], axis=1
            )[:, 0]
            # ragged decode: per-row start offsets; each row's first write
            # lands at slot len_b, overwriting the pad K/V before any mask
            # ever exposes it
            pos = jnp.asarray(lengths, jnp.int32)
        tok = self._sample(last, k0)[:, None]
        steps = self.cfg.max_new_tokens - 1
        if self.cfg.decode_impl == "scan":
            toks, _ = self._decode_scan(steps, self.params, tok, caches, pos, key)
            out = jnp.concatenate([tok, toks.T], axis=1)
        else:  # python-loop reference (one dispatch per step)
            outs = [tok]
            for _ in range(steps):
                lg, caches = self._decode(self.params, tok, caches, pos)
                key, kt = jax.random.split(key)
                tok = self._sample(lg, kt)[:, None]
                outs.append(tok)
                pos = pos + 1
            out = jnp.concatenate(outs, axis=1)
        return np.asarray(out)

    def serve_requests(
        self, requests: list[np.ndarray], batch_size: int = 8, seed: int = 0
    ) -> list[np.ndarray]:
        """Bucket requests BY LENGTH into fixed batches (pad with copies) and
        drain bucket by bucket — the batched-serving driver used by
        examples/serve_kan.py.  Length-sorting means each bucket pads to its
        own max prompt length, not the global max.  Mixed-length buckets
        RIGHT-pad and thread the true lengths through ``generate``, so a
        request's output never depends on its batch-mates or the padding;
        equal-length buckets (the common case after sorting) skip the
        length plumbing and keep the synchronized scalar-position decode."""
        order = sorted(range(len(requests)), key=lambda i: requests[i].shape[0])
        results: list[np.ndarray | None] = [None] * len(requests)
        for bi, start in enumerate(range(0, len(order), batch_size)):
            idxs = order[start : start + batch_size]
            bucket = [requests[i] for i in idxs]
            T = max(r.shape[0] for r in bucket)
            lens = np.asarray([r.shape[0] for r in bucket], np.int32)
            padded = np.stack(
                [np.pad(r, (0, T - r.shape[0]), constant_values=0) for r in bucket]
            )
            while padded.shape[0] < batch_size:
                padded = np.concatenate([padded, padded[-1:]], axis=0)
                lens = np.concatenate([lens, lens[-1:]], axis=0)
            gen = self.generate(
                padded.astype(np.int32), seed=seed + bi,
                lengths=None if bool((lens == T).all()) else lens,
            )
            for j, i in enumerate(idxs):
                results[i] = gen[j]
        return results  # type: ignore[return-value]
