"""Batched serving engine: prefill + decode with KV caches.

The engine jits one ``prefill`` per (batch, seq) bucket and ONE
scan-over-steps decode program per batch shape: the whole generation after
prefill is a single compiled ``jax.lax.scan`` (``max_new_tokens`` static),
so a request costs two XLA dispatches instead of ``max_new_tokens`` Python
round-trips.

Two serving drivers share that program:

* ``serve_requests`` — static bucketing: requests are length-sorted into
  fixed batches and each bucket drains to ``max_new_tokens`` (finished rows
  keep decoding into dead slots — the idle-PE problem in software);
* ``serve_continuous`` — true continuous batching: a slot table
  (``serve/scheduler.py``) runs fixed-shape jitted decode *chunks*
  (``chunk_steps``-long scans with per-row EOS latching) and swaps finished
  slots for queued requests between chunks via
  ``lm.prefill_into_slots`` — queued requests' KV is prefilled and spliced into
  a live batch cache row.

Padding is **right**-padding with per-request start offsets: real tokens
sit at positions ``0..len-1``, causal attention means no real token ever
attends a pad, each request samples from the logits at its *own* last real
position, and decode starts ragged at ``pos_b = len_b`` (overwriting pad
cache slots before they become attendable).

Sampling is **per-row**: each row's PRNG key chain is derived from its
*request id* (``fold_in(PRNGKey(seed), request_id)``, then one split per
emitted token), never from its batch position — so even ``temperature >
0`` generation is bit-invariant to batch-mates, padding, and scheduling
(static vs continuous).  An earlier revision drew all rows' noise from one
batch-wide key, making sampled outputs depend on bucket composition.

EOS (``ServeConfig.eos_id >= 0``) latches per row: the EOS token itself is
emitted, every later step of that row emits ``pad_id`` and the row's
position freezes (its cache stops growing).  ``eos_id = -1`` (default)
never matches a real token id, so the same compiled program reproduces the
never-stop behavior exactly.  Under both greedy and sampled decoding a
request's full ``max_new``-token output (EOS, then pads) is bit-identical
between a solo ``generate`` call and any scheduling of
``serve_requests``/``serve_continuous`` (regression-tested).

Caveat: ragged decode into *windowed* (ring-buffer) attention layers can
still attend stale pad slots once a row's position wraps the window; the
KAN serving configs use full attention, where the invariance is exact.
SSM/LSTM block states are sequential and not pad-invariant under any
padding scheme; equal-length buckets avoid padding entirely.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.serve.scheduler import ContinuousScheduler


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 512
    max_new_tokens: int = 64
    temperature: float = 0.0
    eos_id: int = -1             # -1: never stops early
    pad_id: int = 0              # emitted after a row latches on EOS
    compute_dtype: str = "float32"
    decode_impl: str = "scan"    # "scan" (one compiled program) | "loop"


class Engine:
    def __init__(self, params, model_cfg, serve_cfg: ServeConfig):
        self.params = params
        self.model = model_cfg
        self.cfg = serve_cfg
        self._dt = jnp.float32 if serve_cfg.compute_dtype == "float32" else jnp.bfloat16
        self.last_serve_stats: dict | None = None

        self._prefill = jax.jit(
            lambda p, inputs: lm.prefill(
                p, self.model, inputs, self.cfg.max_seq, self._dt
            )
        )
        self._decode = jax.jit(
            lambda p, tok, caches, pos: lm.decode_step(
                p, self.model, tok, caches, pos, self._dt
            ),
            donate_argnums=(2,),   # caches update in place
        )
        # scan decode: the whole generation (or one continuous-batching
        # chunk) is one compiled program; retraces per static step count
        self._decode_scan = jax.jit(
            self._scan_impl, static_argnums=(0,), donate_argnums=(3,)
        )
        # continuous batching: prefill an admission *group* of k queued
        # requests in ONE dispatch and splice them into their slots
        # (retraces once per (k, padded prompt length) group shape — slots
        # free in bursts at chunk boundaries, so k-batching amortizes the
        # prefill dispatch overhead that dominates one-at-a-time refills)
        self._prefill_insert = jax.jit(
            lambda p, toks, lengths, slots, caches: lm.prefill_into_slots(
                p, self.model, toks, lengths, slots, caches,
                self.cfg.max_seq, self._dt,
            ),
            donate_argnums=(4,),
        )
        # per-row key derivation + first-token sampling, shared by generate
        # and slot admission (jitted: the eager vmap path costs ms per call)
        self._keys_first = jax.jit(self._keys_first_impl)

    # ------------------------------------------------------------------
    # per-row PRNG: key chain = fold_in(base, request_id), split per token
    # ------------------------------------------------------------------

    @staticmethod
    def _row_key_pairs(base_key, request_ids: jax.Array) -> jax.Array:
        """(B,) request ids -> (B, 2, 2): [:, 0] the carried chain key,
        [:, 1] the first sampling key.  vmap of split == per-row split, so
        a solo call and any batched call agree bit-for-bit."""
        return jax.vmap(
            lambda r: jax.random.split(jax.random.fold_in(base_key, r))
        )(request_ids.astype(jnp.int32))

    def _keys_first_impl(self, base_key, request_ids, last_logits):
        """-> (carry keys (B, 2), first sampled token (B,)): each row's key
        chain and its first token, from the logits at its last real prompt
        position.  One definition serves solo ``generate`` and continuous
        slot admission, so the two are bit-identical by construction."""
        pairs = self._row_key_pairs(base_key, request_ids)
        return pairs[:, 0], self._sample(last_logits, pairs[:, 1])

    def _sample(self, logits: jax.Array, step_keys: jax.Array) -> jax.Array:
        """logits (B, vocab), step_keys (B, 2) — one key per row."""
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t = self.cfg.temperature
        return jax.vmap(
            lambda k, lg: jax.random.categorical(k, lg / t)
        )(step_keys, logits).astype(jnp.int32)

    def _scan_impl(self, steps, params, tok0, caches, pos0, keys0, eos_hit0,
                   eos_id, pad_id):
        """(steps static) scan body == one loop iteration of the unrolled
        decode, so scan and loop are bit-identical (tested).

        Per-row EOS latching: once row b emits ``eos_id`` every later step
        emits ``pad_id`` and (when ``pos`` is per-row) its position
        freezes.  ``eos_id``/``pad_id`` are traced scalars — one compiled
        program serves every eos choice, and ``eos_id = -1`` never matches
        a sampled token (ids are >= 0), reproducing never-stop exactly.
        Returns ``(toks (steps, B), tok_last, caches, pos, keys, eos_hit)``
        — the full carry, so continuous batching can resume the next chunk
        where this one left off.
        """

        def body(carry, _):
            tok, caches, pos, keys, eos_hit = carry
            lg, caches = lm.decode_step(
                params, self.model, tok, caches, pos, self._dt
            )
            pairs = jax.vmap(jax.random.split)(keys)
            keys, kt = pairs[:, 0], pairs[:, 1]
            nxt = self._sample(lg, kt)
            emitted = jnp.where(eos_hit, pad_id, nxt)
            eos_new = eos_hit | (nxt == eos_id)
            if pos.ndim == 0:      # synchronized scalar-position decode
                pos = pos + 1
            else:                  # ragged/continuous: latched rows freeze
                pos = jnp.where(eos_hit, pos, pos + 1)
            return (emitted[:, None], caches, pos, keys, eos_new), emitted

        (tok, caches, pos, keys, eos_hit), toks = jax.lax.scan(
            body, (tok0, caches, pos0, keys0, eos_hit0), None, length=steps
        )
        return toks, tok, caches, pos, keys, eos_hit   # toks: (steps, B)

    def generate(
        self,
        prompts: np.ndarray,
        seed: int = 0,
        lengths: np.ndarray | None = None,
        request_ids: np.ndarray | None = None,
        max_new: int | None = None,
        eos_id: int | None = None,
    ) -> np.ndarray:
        """prompts: (B, T_prompt) int32 -> (B, max_new) int32.

        ``lengths`` (optional, (B,)): true prompt lengths for right-padded
        prompts.  Each row then samples from the logits at its own last real
        token and decodes from its own start offset — generation is
        invariant to batch-mates and padding (module docstring).  Without
        ``lengths`` every row is taken as full-length (synchronized decode,
        collective-free scalar-position cache writes).

        ``request_ids`` (optional, (B,)): per-row sampling identity; rows
        with the same id draw the same noise in any batch (defaults to
        ``arange(B)``).  ``max_new``/``eos_id`` override the config values
        per call (``max_new`` retraces the scan; ``eos_id`` does not).
        Rows that emit ``eos_id`` latch: the output carries the EOS token
        followed by ``pad_id`` up to the fixed ``max_new`` length.
        """
        B, T = prompts.shape
        max_new = self.cfg.max_new_tokens if max_new is None else int(max_new)
        eos = self.cfg.eos_id if eos_id is None else int(eos_id)
        assert max_new >= 1 and T + max_new <= self.cfg.max_seq
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        rids = (
            np.arange(B, dtype=np.int32)
            if request_ids is None
            else np.asarray(request_ids, np.int32)
        )
        assert rids.shape == (B,)
        if lengths is None:
            last = logits[:, T - 1]
            # synchronized decode (scalar position): collective-free writes
            # — unless EOS can latch rows at different steps, which needs
            # per-row frozen positions
            pos = (
                jnp.asarray(T, jnp.int32)
                if eos < 0
                else jnp.full((B,), T, jnp.int32)
            )
        else:
            lengths = np.asarray(lengths, np.int32)
            assert lengths.shape == (B,), (lengths.shape, B)
            assert lengths.min() >= 1 and lengths.max() <= T
            last = jnp.take_along_axis(
                logits, jnp.asarray(lengths - 1)[:, None, None], axis=1
            )[:, 0]
            # ragged decode: per-row start offsets; each row's first write
            # lands at slot len_b, overwriting the pad K/V before any mask
            # ever exposes it
            pos = jnp.asarray(lengths, jnp.int32)
        keys, tok0 = self._keys_first(
            jax.random.PRNGKey(seed), jnp.asarray(rids), last
        )
        tok = tok0[:, None]
        eos_hit = tok[:, 0] == eos          # eos = -1 never matches
        eos_a, pad_a = jnp.int32(eos), jnp.int32(self.cfg.pad_id)
        steps = max_new - 1
        if self.cfg.decode_impl == "scan":
            toks, _, _, _, _, _ = self._decode_scan(
                steps, self.params, tok, caches, pos, keys, eos_hit,
                eos_a, pad_a,
            )
            out = jnp.concatenate([tok, toks.T], axis=1)
        else:  # python-loop reference (one dispatch per step), mirrors body
            outs = [tok]
            for _ in range(steps):
                lg, caches = self._decode(self.params, tok, caches, pos)
                pairs = jax.vmap(jax.random.split)(keys)
                keys, kt = pairs[:, 0], pairs[:, 1]
                nxt = self._sample(lg, kt)
                emitted = jnp.where(eos_hit, pad_a, nxt)
                if pos.ndim == 0:
                    pos = pos + 1
                else:
                    pos = jnp.where(eos_hit, pos, pos + 1)
                eos_hit = eos_hit | (nxt == eos_a)
                tok = emitted[:, None]
                outs.append(tok)
            out = jnp.concatenate(outs, axis=1)
        return np.asarray(out)

    def serve_requests(
        self, requests: list[np.ndarray], batch_size: int = 8, seed: int = 0
    ) -> list[np.ndarray]:
        """Bucket requests BY LENGTH into fixed batches (pad with copies) and
        drain bucket by bucket — the *static* batched-serving driver.
        Length-sorting means each bucket pads to its own max prompt length,
        not the global max.  Mixed-length buckets RIGHT-pad and thread the
        true lengths through ``generate``; per-row sampling keys are derived
        from each request's index in ``requests``, so outputs (greedy OR
        sampled) never depend on batch-mates or padding.  Finished (EOS)
        rows latch but their slots are NOT recycled — see
        :meth:`serve_continuous` for that."""
        order = sorted(range(len(requests)), key=lambda i: requests[i].shape[0])
        results: list[np.ndarray | None] = [None] * len(requests)
        t0 = time.perf_counter()
        buckets: list[dict] = []
        for start in range(0, len(order), batch_size):
            idxs = order[start : start + batch_size]
            bucket = [requests[i] for i in idxs]
            T = max(r.shape[0] for r in bucket)
            lens = np.asarray([r.shape[0] for r in bucket], np.int32)
            rids = np.asarray(idxs, np.int32)
            padded = np.stack(
                [np.pad(r, (0, T - r.shape[0]), constant_values=0) for r in bucket]
            )
            while padded.shape[0] < batch_size:
                padded = np.concatenate([padded, padded[-1:]], axis=0)
                lens = np.concatenate([lens, lens[-1:]], axis=0)
                rids = np.concatenate([rids, rids[-1:]], axis=0)
            gen = self.generate(
                padded.astype(np.int32), seed=seed,
                lengths=None if bool((lens == T).all()) else lens,
                request_ids=rids,
            )
            for j, i in enumerate(idxs):
                results[i] = gen[j]
            # a request "completes" when its bucket drains — the latency
            # accounting the serving benchmark compares against continuous
            buckets.append({
                "request_ids": idxs,
                "rows": int(padded.shape[0]),
                "done_s": time.perf_counter() - t0,
            })
        self.last_serve_stats = {
            "wall_s": time.perf_counter() - t0,
            "buckets": buckets,
            "request_latency_s": [
                next(b["done_s"] for b in buckets if i in b["request_ids"])
                for i in range(len(requests))
            ],
        }
        return results  # type: ignore[return-value]

    # ------------------------------------------------------------------
    # continuous batching
    # ------------------------------------------------------------------

    def serve_continuous(
        self,
        requests: list[np.ndarray],
        slots: int = 8,
        chunk_steps: int = 8,
        seed: int = 0,
        max_new: int | list[int] | None = None,
        prompt_pad_multiple: int = 8,
    ) -> list[np.ndarray]:
        """True continuous batching: a ``slots``-row decode batch whose rows
        are recycled the moment a request finishes (EOS latch or token
        budget), instead of draining with the bucket.

        The loop alternates two fixed-shape jitted programs: a decode
        *chunk* (``chunk_steps`` scan steps over all slots, per-row EOS
        latching/frozen positions for dead rows) and ``lm.prefill_into_slots``
        (one queued request prefilled at a bucketed prompt length and its
        KV spliced into the freed row).  Between chunks the host scheduler
        (``serve/scheduler.py``) retires finished slots and admits from the
        FIFO queue.  Recompile boundaries: one trace per ``chunk_steps``
        value and one per padded prompt length (``prompt_pad_multiple``
        buckets them).

        ``max_new``: per-request (list) or global token budgets; default
        ``cfg.max_new_tokens``.  Each request's output has exactly its
        budget's length, padded with ``pad_id`` after EOS — bit-identical
        to a solo :meth:`generate` call with the same ``request_id`` (its
        index in ``requests``), for greedy AND sampled decoding.

        Sets ``self.last_serve_stats`` (scheduler counters, per-request
        latency, wall time) for the serving benchmark.
        """
        n = len(requests)
        if max_new is None:
            budgets = [self.cfg.max_new_tokens] * n
        elif isinstance(max_new, int):
            budgets = [max_new] * n
        else:
            budgets = [int(m) for m in max_new]
            assert len(budgets) == n
        eos, pad = self.cfg.eos_id, self.cfg.pad_id
        for r, m in zip(requests, budgets):
            assert m >= 1 and r.shape[0] + m <= self.cfg.max_seq, (
                f"prompt {r.shape[0]} + max_new {m} > max_seq {self.cfg.max_seq}"
            )
        assert chunk_steps >= 1 and slots >= 1

        sched = ContinuousScheduler(slots, range(n))
        caches = lm.init_caches(self.model, slots, self.cfg.max_seq, self._dt)
        # host mirrors of the per-slot device state fed to each chunk
        tok = np.zeros((slots, 1), np.int32)
        pos = np.zeros((slots,), np.int32)
        keys = np.zeros((slots, 2), np.uint32)
        eos_hit = np.ones((slots,), bool)      # empty slots stay latched
        base = jax.random.PRNGKey(seed)
        bufs: list[list[int]] = [[] for _ in range(n)]
        outputs: list[np.ndarray | None] = [None] * n
        t0 = time.perf_counter()
        latency = [0.0] * n

        def finalize(rid: int) -> None:
            got = bufs[rid][: budgets[rid]]
            out = np.full((budgets[rid],), pad, np.int32)
            out[: len(got)] = got
            outputs[rid] = out
            latency[rid] = time.perf_counter() - t0

        def admit_all():
            nonlocal caches
            while True:
                ready = sched.admit_ready()
                if not ready:
                    return
                # one prefill dispatch per (padded length) admission group
                groups: dict[int, list[tuple[int, int]]] = {}
                for b, rid in ready:
                    L = requests[rid].shape[0]
                    # clamp: padding past L is causally invisible, but the
                    # prefilled cache must still fit the (slots, max_seq)
                    # live cache it is spliced into
                    t_pad = min(
                        -(-L // prompt_pad_multiple) * prompt_pad_multiple,
                        self.cfg.max_seq,
                    )
                    groups.setdefault(t_pad, []).append((b, rid))
                for t_pad, grp in sorted(groups.items()):
                    slots_a = np.asarray([b for b, _ in grp], np.int32)
                    rids_a = np.asarray([rid for _, rid in grp], np.int32)
                    lens = np.asarray(
                        [requests[rid].shape[0] for _, rid in grp], np.int32
                    )
                    padded = np.stack([
                        np.pad(requests[rid], (0, t_pad - requests[rid].shape[0]))
                        for _, rid in grp
                    ]).astype(np.int32)
                    last, caches = self._prefill_insert(
                        self.params, padded, lens, slots_a, caches
                    )
                    kcs_d, firsts_d = self._keys_first(
                        base, jnp.asarray(rids_a), last
                    )
                    kcs, firsts = np.asarray(kcs_d), np.asarray(firsts_d)
                    for j, (b, rid) in enumerate(grp):
                        first = int(firsts[j])
                        bufs[rid].append(first)
                        hit = eos >= 0 and first == eos
                        if sched.confirm_admit(b, rid, int(lens[j]),
                                               budgets[rid] - 1, hit):
                            finalize(rid)       # done at admission: the
                            sched.retire(b)     # freed slot is refilled by
                            eos_hit[b] = True   # the next round of the loop
                        else:
                            tok[b, 0] = first
                            pos[b] = lens[j]
                            keys[b] = kcs[j]
                            eos_hit[b] = False

        eos_a, pad_a = jnp.int32(eos), jnp.int32(pad)
        while True:
            admit_all()
            sched.check_invariants()
            if not sched.can_run_chunk():
                break
            toks, tok_l, caches, pos_l, keys_l, eos_l = self._decode_scan(
                chunk_steps, self.params, jnp.asarray(tok), caches,
                jnp.asarray(pos), jnp.asarray(keys), jnp.asarray(eos_hit),
                eos_a, pad_a,
            )
            # one device->host transfer; np.array copies because the host
            # mirrors are written by admission/retirement below
            toks, tok, pos, keys, eos_hit = [
                np.array(a)
                for a in jax.device_get((toks, tok_l, pos_l, keys_l, eos_l))
            ]
            if eos >= 0:
                # first in-chunk EOS emission per slot (chunk_steps if
                # none): post-EOS pads count as waste in the utilization
                hits = toks == eos
                eos_steps = np.where(
                    hits.any(axis=0), hits.argmax(axis=0), chunk_steps
                )
            else:
                eos_steps = None
            for b, rid, n_keep, finished in sched.complete_chunk(
                chunk_steps, eos_hit, eos_steps
            ):
                bufs[rid].extend(int(t) for t in toks[:n_keep, b])
                if finished:
                    finalize(rid)
                    sched.retire(b)
                    eos_hit[b] = True

        sched.check_invariants()
        assert all(o is not None for o in outputs)
        self.last_serve_stats = {
            **sched.stats(),
            "wall_s": time.perf_counter() - t0,
            "request_latency_s": latency,
            "useful_tokens": int(sum(budget_used(bufs[i], budgets[i], eos)
                                     for i in range(n))),
        }
        return outputs  # type: ignore[return-value]


def budget_used(buf: list[int], budget: int, eos: int) -> int:
    """Tokens a request actually *used*: up to and including its EOS, else
    its full budget (serving-benchmark accounting)."""
    toks = buf[:budget]
    if eos >= 0 and eos in toks:
        return toks.index(eos) + 1
    return len(toks)
