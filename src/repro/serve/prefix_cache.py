"""Prefix cache: hash full prompt-token blocks -> reuse their KV blocks.

Shared prompt prefixes (system prompts, few-shot preambles) are prefilled
and stored once per *request* by the dense engine.  The paged subsystem
deduplicates them at **block granularity**: the i-th full block of a
prompt is keyed by a chained digest

``key_i = H(key_{i-1} || tokens[i*bs : (i+1)*bs])``

so a cache hit on ``key_i`` guarantees the *entire* token prefix up to
``(i+1)*bs`` matches — position-dependent KV (rotary) is safe to reuse.
Only FULL blocks are ever cached; a prompt's trailing partial block is
private to its request.

At admission the engine takes the longest chain of cached blocks, capped at
``(len - 1) // bs`` so at least the last prompt token is always recomputed
(its logits seed sampling) and so decode writes never land in a shared
block — which is what keeps copy-on-write off serving's hot path
(DESIGN.md §3b).  ``prefill_into_pages`` then computes only the uncached
suffix.

Eviction is LRU over cache entries whose block the pool reports as
*cache-only* (refcount 1): entries whose block is still mapped by a live
request are skipped, and the map entry is removed in the same step the
pool reference drops — a freed-then-reallocated block can never serve a
stale hit.

Host-side Python only, like ``serve/kv_pool.py``; bit-identity of reuse is
the engine's contract (reused blocks hold exactly the KV the dense path
would recompute — tested), while this module guarantees *which* reuse is
legal.  Int8 KV-quantized caches disable prefix reuse (the engine forces
``start = 0``): dense prefill attends raw K/V while reused blocks could
only supply dequantized values, which would break bit-identity with solo
``generate``.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict

import numpy as np


def _digest(parent: bytes, tokens: np.ndarray) -> bytes:
    h = hashlib.sha1(parent)
    h.update(np.ascontiguousarray(tokens, dtype=np.int32).tobytes())
    return h.digest()


def block_keys(tokens: np.ndarray, block_size: int) -> list[bytes]:
    """Chained digests of every FULL block of ``tokens``."""
    keys, parent = [], b"root"
    for i in range(len(tokens) // block_size):
        parent = _digest(parent, tokens[i * block_size:(i + 1) * block_size])
        keys.append(parent)
    return keys


class PrefixCache:
    def __init__(self, block_size: int):
        assert block_size >= 1
        self.block_size = block_size
        # insertion-ordered: front = least recently used (touch moves to
        # the back), so eviction scans from the front instead of sorting
        self._map: OrderedDict[bytes, int] = OrderedDict()  # key -> block id
        self._key_of: dict[int, bytes] = {}       # block id -> chained key
        self.lookups = 0                          # admissions probed
        self.hit_blocks = 0                       # probed blocks, present
        self.miss_blocks = 0                      # probed blocks, absent
        self.n_evictions = 0

    def __len__(self) -> int:
        return len(self._map)

    def _touch(self, key: bytes) -> None:
        self._map.move_to_end(key)

    # ------------------------------ lookup ----------------------------------

    def match(
        self, tokens: np.ndarray, keys: list[bytes] | None = None
    ) -> tuple[int, list[int], list[bytes]]:
        """Longest reusable prefix of ``tokens`` at admission.

        Returns ``(n_hit, blocks, keys)``: the first ``n_hit`` chained keys
        were found (their physical ``blocks`` can be shared), capped at
        ``(len(tokens) - 1) // block_size`` so the last prompt token is
        always recomputed; ``keys`` is the FULL key chain (hit or not) so
        the caller can register the blocks it goes on to compute.

        ``match`` records NO hit/miss statistics — a block-starved
        admission defers and re-probes every serve-loop iteration, and
        counting each retry would inflate the exported hit rate exactly in
        the pool-pressure regimes it is meant to describe.  Callers invoke
        :meth:`record_admission` once per admission that actually binds.
        (Matched keys are still LRU-touched: a deferred request's blocks
        staying warm is the desired eviction behavior.)

        ``keys`` (optional): a previously computed chain for these exact
        tokens — deferred admissions re-probe every serve-loop iteration,
        and the chain is immutable per prompt, so callers memoize it
        instead of re-hashing O(prompt) sha1 per retry.
        """
        if keys is None:
            keys = block_keys(tokens, self.block_size)
        cap = max((len(tokens) - 1) // self.block_size, 0)
        blocks: list[int] = []
        for key in keys[:cap]:
            if key not in self._map:
                break
            blocks.append(self._map[key])
            self._touch(key)
        return len(blocks), blocks, keys

    def record_admission(self, n_hit: int, n_tokens: int) -> None:
        """Count one *bound* admission's probe outcome: ``n_hit`` blocks
        served from cache; only blocks actually probed count toward the
        rate (the chain stops at the first miss, and keys beyond the reuse
        cap are never consulted)."""
        cap = max((n_tokens - 1) // self.block_size, 0)
        self.lookups += 1
        self.hit_blocks += n_hit
        self.miss_blocks += 1 if n_hit < cap else 0

    # ---------------------------- registration ------------------------------

    def insert(self, key: bytes, block: int) -> bool:
        """Register ``key -> block`` (skipped if the key is already cached
        — first writer wins, later identical blocks are duplicates the
        *next* admission will avoid).  Returns True when registered; the
        caller then takes a pool ``cache_ref`` on the block."""
        if key in self._map:
            return False
        assert block not in self._key_of, (
            f"block {block} already registered under another key"
        )
        self._map[key] = block
        self._key_of[block] = key
        self._touch(key)
        return True

    def holds(self, block: int) -> bool:
        return block in self._key_of

    # ------------------------------ eviction --------------------------------

    def evict_lru(self, pool) -> int | None:
        """Evict the least-recently-used entry whose block the pool reports
        as cache-only (sole reference), dropping the pool's cache reference
        in the same step.  Returns the freed block id, or None when nothing
        is evictable (every cached block is still mapped by a live
        request).  The map iterates in LRU order (``_touch`` moves entries
        to the back), so this is a front scan, not a sort."""
        for key in self._map:            # front = LRU
            block = self._map[key]
            if pool.cache_only(block):
                del self._map[key]
                del self._key_of[block]
                freed = pool.cache_unref(block)
                assert freed, "cache-only block failed to free"
                self.n_evictions += 1
                return block
        return None

    def flush(self, pool) -> int:
        """Evict every evictable entry (drain teardown): map entries and
        pool cache references drop together, so the cache can never hand
        out a block the pool has since freed and re-allocated.  Returns how
        many blocks freed."""
        n = 0
        while self.evict_lru(pool) is not None:
            n += 1
        return n

    # ---------------------------- observability -----------------------------

    def stats(self) -> dict:
        probed = self.hit_blocks + self.miss_blocks
        return {
            "prefix_entries": len(self._map),
            "prefix_lookups": self.lookups,
            "prefix_hit_blocks": self.hit_blocks,
            "prefix_block_hit_rate": self.hit_blocks / probed if probed else 0.0,
            "prefix_evictions": self.n_evictions,
        }
