"""Slot-level continuous-batching scheduler (host-side bookkeeping).

The software analogue of the paper's idle-PE problem: a static batch keeps
decoding into dead rows until the whole bucket drains, exactly like a
systolic array clocking zeros through unused PEs.  Continuous batching
keeps every batch row ("slot") busy: when a request finishes (EOS or token
budget), its slot is retired and the next queued request is admitted at the
following chunk boundary.

This module is pure host-side Python — no jax.  The :class:`Engine` owns
the device state (KV caches, positions, PRNG keys, EOS latches); the
scheduler owns the *decision* state:

* :class:`SlotTable` — per-slot ``{request_id, pos, remaining, eos_hit}``
  mirroring the device arrays, plus occupancy;
* :class:`AdmissionQueue` — FIFO of waiting requests;
* :class:`ContinuousScheduler` — admission + retirement policy and the
  utilization accounting the serving benchmark reports.

Invariants (asserted by :meth:`ContinuousScheduler.check_invariants` and
exercised by ``tests/test_continuous_serving.py``): a request occupies at
most one slot, a slot holds at most one live request, every submitted
request is eventually served exactly once, and all slots are free once the
queue and table drain.
"""

from __future__ import annotations

import dataclasses
from collections import deque


@dataclasses.dataclass
class Slot:
    """One batch row of the live decode batch.

    ``eos_hit=True`` doubles as "this row is dead": empty slots and retired
    slots are latched so the device-side scan masks their emissions to
    ``pad_id`` and freezes their position.
    """

    request_id: int = -1
    pos: int = 0           # next cache write position (== tokens in cache)
    remaining: int = 0     # decode tokens still owed (first token is paid
                           # for by prefill, so this starts at max_new - 1)
    eos_hit: bool = True   # latched: empty, finished, or EOS'd
    useful_steps: int = 0  # token-steps credited to THIS occupancy — rolled
                           # back if the request is preempted (its emitted
                           # tokens are discarded and re-generated)

    @property
    def occupied(self) -> bool:
        return self.request_id >= 0


class SlotTable:
    def __init__(self, n_slots: int):
        assert n_slots >= 1
        self.slots = [Slot() for _ in range(n_slots)]

    def __len__(self) -> int:
        return len(self.slots)

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if not s.occupied]

    def occupied_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s.occupied]

    def live_slots(self) -> list[int]:
        """Occupied AND not latched — rows that still produce real tokens."""
        return [i for i, s in enumerate(self.slots)
                if s.occupied and not s.eos_hit]

    def admit(self, slot: int, request_id: int, pos: int, remaining: int,
              eos_hit: bool = False) -> None:
        s = self.slots[slot]
        assert not s.occupied, f"slot {slot} already holds request {s.request_id}"
        assert request_id >= 0 and pos >= 0 and remaining >= 0
        self.slots[slot] = Slot(request_id, pos, remaining, eos_hit)

    def retire(self, slot: int) -> int:
        """Free the slot, returning the request id it held."""
        s = self.slots[slot]
        assert s.occupied, f"slot {slot} is already free"
        rid = s.request_id
        self.slots[slot] = Slot()
        return rid


class AdmissionQueue:
    """FIFO of request ids waiting for a slot."""

    def __init__(self, request_ids=()):
        self._q: deque[int] = deque(request_ids)

    def push(self, request_id: int) -> None:
        self._q.append(request_id)

    def push_front(self, request_id: int) -> None:
        """Head-of-queue insert: a preempted request re-admits before any
        newer arrivals, so preemption can't starve it (FIFO fairness up to
        the preemption itself)."""
        self._q.appendleft(request_id)

    def pop(self) -> int:
        return self._q.popleft()

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)


class ContinuousScheduler:
    """Admission + retirement policy over a :class:`SlotTable`.

    The engine calls, per iteration of its serve loop:

    1. ``admit_ready()`` — every admissible ``(slot, request_id)`` pair in
       one burst (ONE grouped prefill dispatch); then ``confirm_admit(...)``
       per pair with the device-side facts (start position, budget, whether
       the very first token already hit EOS);
    2. run one fixed-shape decode chunk;
    3. ``complete_chunk(chunk_steps, eos_hits)`` — advance per-slot
       bookkeeping, collect ``(slot, request_id, n_kept)`` for every slot,
       and retire finished ones.
    """

    def __init__(self, n_slots: int, request_ids=()):
        self.table = SlotTable(n_slots)
        self.queue = AdmissionQueue(request_ids)
        self.n_submitted = len(self.queue)
        self.served: list[int] = []
        # utilization accounting: a token-step is one slot x one decode step
        self.useful_token_steps = 0
        self.total_token_steps = 0
        self.chunks_run = 0
        # admission recency, for the paged engine's preempt-youngest policy
        self._admit_seq = 0
        self._slot_admit_seq = [0] * n_slots
        self.n_preemptions = 0

    # ------------------------------ admission ------------------------------

    def admit_ready(self) -> list[tuple[int, int]]:
        """All (slot, request_id) pairs admissible right now — distinct free
        slots zipped with queue pops, so one burst of retirements can be
        refilled by ONE grouped prefill dispatch.  Callers must
        ``confirm_admit`` every returned pair before asking again."""
        out: list[tuple[int, int]] = []
        for slot in self.table.free_slots():
            if not self.queue:
                break
            out.append((slot, self.queue.pop()))
        return out

    def confirm_admit(self, slot: int, request_id: int, pos: int,
                      remaining: int, eos_hit: bool) -> bool:
        """Record an admitted request; returns True if it is already done
        (budget of one token, or the first token was EOS) — the engine then
        calls :meth:`retire` immediately and the slot is reused without ever
        entering a chunk."""
        done = eos_hit or remaining == 0
        self.table.admit(slot, request_id, pos, remaining, eos_hit=done)
        self._admit_seq += 1
        self._slot_admit_seq[slot] = self._admit_seq
        return done

    def retire(self, slot: int) -> int:
        rid = self.table.retire(slot)
        self.served.append(rid)
        return rid

    # ------------------------------ preemption -----------------------------

    def youngest_live_slot(self) -> int | None:
        """The live slot admitted most recently — the paged engine's
        preemption victim on pool exhaustion (preempting the youngest
        wastes the least completed work and lets older requests drain,
        guaranteeing progress)."""
        live = self.table.live_slots()
        if not live:
            return None
        return max(live, key=lambda b: self._slot_admit_seq[b])

    def preempt(self, slot: int) -> int:
        """Evict a live request from its slot and push it back to the HEAD
        of the admission queue.  Its re-admission restarts generation from
        scratch (preemption-with-recompute): generation is a deterministic
        function of (request id, seed, prompt), so the regenerated stream —
        and therefore the final output — is bit-identical to the
        never-preempted run.  The caller discards the request's partial
        output buffer and frees its cache blocks."""
        s = self.table.slots[slot]
        assert s.occupied and not s.eos_hit, f"slot {slot} not preemptible"
        # the discarded tokens get re-generated and re-counted on the
        # re-run, so their token-steps become waste, not useful work —
        # without this rollback any preempting run inflates utilization
        self.useful_token_steps -= s.useful_steps
        rid = self.table.retire(slot)
        self.queue.push_front(rid)
        self.n_preemptions += 1
        return rid

    # ------------------------------- chunks --------------------------------

    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.table.occupied_slots())

    def can_run_chunk(self) -> bool:
        return bool(self.table.live_slots())

    def complete_chunk(
        self, chunk_steps: int, eos_hits, eos_steps=None
    ) -> list[tuple[int, int, int, bool]]:
        """Account for one finished decode chunk.

        ``eos_hits[b]``: the device EOS latch for slot *b* at chunk end.
        ``eos_steps[b]`` (optional): the in-chunk step index of slot *b*'s
        first EOS emission (``chunk_steps`` if none) — post-EOS pad
        emissions inside the finishing chunk then count as *waste*, not
        useful token-steps, so ``mean_slot_utilization`` stays honest under
        EOS early-exit.  Returns ``(slot, request_id, n_keep, finished)``
        per occupied slot: the engine keeps the first ``n_keep`` of the
        chunk's emitted tokens for that request, and retires the slot if
        ``finished``.
        """
        out: list[tuple[int, int, int, bool]] = []
        self.chunks_run += 1
        self.total_token_steps += chunk_steps * len(self.table)
        for b in self.table.occupied_slots():
            s = self.table.slots[b]
            n_keep = min(chunk_steps, s.remaining)
            hit = bool(eos_hits[b])
            s.remaining -= n_keep
            s.pos += n_keep          # host mirror; device froze latched rows
            s.eos_hit = s.eos_hit or hit
            useful = n_keep
            if eos_steps is not None:
                useful = min(useful, int(eos_steps[b]) + 1)
            self.useful_token_steps += useful
            s.useful_steps += useful
            finished = hit or s.remaining == 0
            out.append((b, s.request_id, n_keep, finished))
        return out

    def complete_spec_window(
        self, window_steps: int, emitted_counts, eos_hits, eos_steps=None
    ) -> list[tuple[int, int, int, bool]]:
        """Account for one finished speculative window (DESIGN.md §9).

        Unlike :meth:`complete_chunk` — where every live slot advances by
        exactly ``chunk_steps`` — a verify window emits a *variable* number
        of tokens per row: ``emitted_counts[b]`` is the device's accepted
        count ``m`` (matched drafts + the bonus token, truncated at an
        in-window EOS; 0 for latched rows).  A row keeps
        ``min(m, remaining)`` of them — the window can overshoot the budget
        on its last emission, so the host clamp is what retires the row.
        ``total_token_steps`` charges the full ``window_steps = k + 1``
        per occupied slot (the capacity the window *could* have emitted):
        rejected drafts are exactly the waste ``mean_slot_utilization``
        should see, making the stat comparable across spec and non-spec
        runs.  ``eos_steps`` has :meth:`complete_chunk` semantics over the
        emitted window rows.  Returns ``(slot, request_id, n_keep,
        finished)`` per occupied slot.
        """
        out: list[tuple[int, int, int, bool]] = []
        self.chunks_run += 1
        self.total_token_steps += window_steps * len(self.table)
        for b in self.table.occupied_slots():
            s = self.table.slots[b]
            n_keep = min(int(emitted_counts[b]), s.remaining)
            hit = bool(eos_hits[b])
            s.remaining -= n_keep
            s.pos += n_keep
            s.eos_hit = s.eos_hit or hit
            useful = n_keep
            if eos_steps is not None:
                useful = min(useful, int(eos_steps[b]) + 1)
            self.useful_token_steps += useful
            s.useful_steps += useful
            finished = hit or s.remaining == 0
            out.append((b, s.request_id, n_keep, finished))
        return out

    # ---------------------------- observability ----------------------------

    def mean_slot_utilization(self) -> float:
        """Fraction of slot x step capacity that produced kept tokens."""
        if self.total_token_steps == 0:
            return 1.0 if not self.n_submitted else 0.0
        return self.useful_token_steps / self.total_token_steps

    def stats(self) -> dict:
        return {
            "n_submitted": self.n_submitted,
            "n_served": len(self.served),
            "chunks_run": self.chunks_run,
            "useful_token_steps": self.useful_token_steps,
            "total_token_steps": self.total_token_steps,
            "mean_slot_utilization": self.mean_slot_utilization(),
            "n_preemptions": self.n_preemptions,
        }

    def check_invariants(self) -> None:
        rids = [s.request_id for s in self.table.slots if s.occupied]
        assert len(rids) == len(set(rids)), f"request in two slots: {rids}"
        assert not (set(rids) & set(self.served)), "served request still slotted"
        if not self.has_work():
            assert len(self.table.free_slots()) == len(self.table), "slot leak"
            assert sorted(self.served) == sorted(set(self.served)), (
                "request served twice"
            )
            assert len(self.served) == self.n_submitted, (
                f"served {len(self.served)} of {self.n_submitted}"
            )
