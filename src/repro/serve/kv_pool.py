"""Paged KV-cache block pool (host-side bookkeeping).

The dense engine preallocates a contiguous ``(slots, max_seq)`` KV row per
batch slot, so admission is capped by *worst-case* cache size: a 5-token
request strands the same HBM as a 500-token one — the memory-shaped
analogue of the paper's idle-PE problem.  Paged serving (DESIGN.md §3b)
carves the preallocated cache arrays into fixed-size *blocks*
(``pool : (n_blocks, block_size, ...)`` per attention layer, one physical
block id addressing every layer's pool, vLLM-style) and binds them to
requests on demand through per-request block tables.

This module is pure host Python — no jax.  It owns the *decision* state of
the paged subsystem, mirroring how ``serve/scheduler.py`` owns slot
decisions:

* :class:`BlockPool` — free list, per-block reference counts, per-request
  block ownership, copy-on-write forks, and the ``blocks_in_use`` watermark
  the benchmark reports.  Physical block 0 is **reserved as the sentinel**:
  empty table entries point at it, and device-side writes that fall outside
  a row's coverage are redirected into it (a trash block whose contents are
  never attendable — the causal mask annihilates them).
* block-count helpers (:func:`blocks_for`, :func:`worst_case_blocks`) shared
  by engine admission validation and tests.

Reference-count convention: a block's refcount is the number of *requests*
whose table currently maps it, plus one if the prefix cache
(``serve/prefix_cache.py``) holds it.  A block returns to the free list
exactly when its refcount reaches zero; after a full drain + cache flush,
``free + 0 == usable`` (asserted by :meth:`check_balanced`, property-tested
in ``tests/test_kv_pool.py`` / ``tests/test_continuous_serving.py``).
"""

from __future__ import annotations

SENTINEL = 0   # physical block 0: reserved trash target, never allocated


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to cover ``n_tokens`` cache positions."""
    assert n_tokens >= 0 and block_size >= 1
    return -(-n_tokens // block_size)


def worst_case_blocks(
    prompt_len: int, max_new: int, chunk_steps: int, block_size: int,
    max_seq: int, spec_k: int = 0,
) -> int:
    """Upper bound on blocks a single request can ever hold.

    Decode chunks advance a live row's position by the full ``chunk_steps``
    even on its final chunk (the scan is fixed-shape; surplus emissions are
    dropped host-side), so the highest written position is
    ``prompt_len + ceil((max_new - 1) / chunk_steps) * chunk_steps - 1`` —
    clamped to ``max_seq`` because out-of-range writes are redirected to the
    sentinel block.  Engine admission validates every request against this
    bound so a single request can always run on an otherwise-empty pool
    (preemption can then always make progress).

    Speculative mode (``spec_k >= 1``) does NOT share the chunk bound: a
    verify window starting at the last live position (``prompt_len +
    max_new - 2``, just before the final emission) writes ``spec_k`` draft
    positions past it, and coverage is trimmed back only *after* the
    window.  The supremum written position is therefore
    ``prompt_len + max_new - 1 + spec_k`` (again clamped to ``max_seq``).
    """
    if spec_k >= 1:
        hi = min(prompt_len + max_new - 1 + spec_k, max_seq)
        return blocks_for(hi, block_size)
    n_chunks = blocks_for(max(max_new - 1, 0), chunk_steps)  # ceil-div
    hi = min(prompt_len + n_chunks * chunk_steps, max_seq)
    return blocks_for(hi, block_size)


class BlockPool:
    """Fixed-size physical block allocator with refcounts and CoW."""

    def __init__(self, n_blocks: int, block_size: int):
        assert n_blocks >= 2, "need at least the sentinel + one usable block"
        assert block_size >= 1
        self.n_blocks = n_blocks
        self.block_size = block_size
        # LIFO free list: recently freed blocks are re-used first (their
        # pool pages are warm); block 0 is never in it.
        self._free = list(range(n_blocks - 1, 0, -1))
        self._ref = [0] * n_blocks
        self._owned: dict[int, list[int]] = {}   # request id -> blocks, in
                                                 # logical order
        self._cache_held: set[int] = set()       # blocks the prefix cache refs
        self.watermark = 0                        # max blocks ever in use
        self.n_allocs = 0
        self.n_cow = 0

    # ------------------------------ queries --------------------------------

    @property
    def usable(self) -> int:
        return self.n_blocks - 1                  # minus the sentinel

    def free_count(self) -> int:
        return len(self._free)

    def in_use(self) -> int:
        return self.usable - len(self._free)

    def refcount(self, block: int) -> int:
        return self._ref[block]

    def owned_blocks(self, rid: int) -> list[int]:
        return list(self._owned.get(rid, ()))

    # ---------------------------- allocation -------------------------------

    def alloc(self, rid: int, n: int) -> list[int]:
        """Allocate ``n`` fresh blocks (refcount 1 each) onto ``rid``'s
        table.  Callers must check :meth:`free_count` (and evict / preempt)
        first — an insufficient pool raises."""
        if n > len(self._free):
            raise MemoryError(
                f"pool exhausted: request {rid} needs {n} blocks, "
                f"{len(self._free)} free of {self.usable} usable"
            )
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            assert self._ref[b] == 0
            self._ref[b] = 1
        self._owned.setdefault(rid, []).extend(out)
        self.n_allocs += n
        self.watermark = max(self.watermark, self.in_use())
        return out

    def share(self, rid: int, blocks: list[int]) -> None:
        """Append already-live ``blocks`` (a prefix-cache hit) to ``rid``'s
        table, bumping each refcount.  Must precede any :meth:`alloc` for
        ``rid`` — shared prefix blocks sit at the front of the table."""
        assert rid not in self._owned, f"request {rid} already holds blocks"
        for b in blocks:
            assert b != SENTINEL and self._ref[b] > 0, (
                f"block {b} is not live (ref={self._ref[b]})"
            )
            self._ref[b] += 1
        self._owned[rid] = list(blocks)

    def release_request(self, rid: int) -> list[int]:
        """Drop ``rid``'s reference on every block it holds (retirement or
        preemption).  Returns the blocks that actually became free; blocks
        also held by the prefix cache (or by other requests' tables) stay
        live."""
        freed = []
        for b in self._owned.pop(rid, ()):  # noqa: B020
            self._ref[b] -= 1
            assert self._ref[b] >= 0
            if self._ref[b] == 0:
                self._free.append(b)
                freed.append(b)
        return freed

    def trim_request(self, rid: int, keep: int) -> list[int]:
        """Roll back ``rid``'s table to its first ``keep`` blocks, releasing
        the tail (speculative rejection: the verify window over-covered
        positions the accepted prefix never reached — DESIGN.md §9).

        The tail is always *request-exclusive fresh* blocks, never shared
        prefix: admission caps prefix reuse at ``(len - 1) // block_size``
        full blocks, so the shared-block count is at most
        ``blocks_for(prompt_len)``, and the engine only trims to
        ``keep = blocks_for(pos')`` with ``pos' >= prompt_len`` — shared
        blocks all sit at table indices ``< keep``.  Asserted below: a
        trimmed block must be exclusively ours (refcount drops to zero, the
        block frees immediately — rollback needs no CoW and no device copy;
        the garbage KV inside is unreachable once the table entry is gone).
        Returns the freed blocks.
        """
        table = self._owned.get(rid, [])
        assert 0 <= keep <= len(table), (rid, keep, len(table))
        freed = []
        for b in table[keep:]:
            assert b != SENTINEL and b not in self._cache_held, (
                f"trim would release shared/cached block {b} of request {rid}"
            )
            self._ref[b] -= 1
            assert self._ref[b] == 0, (
                f"trimmed block {b} still referenced (ref={self._ref[b]})"
            )
            self._free.append(b)
            freed.append(b)
        del table[keep:]
        if not table:
            self._owned.pop(rid, None)
        return freed

    # --------------------------- prefix-cache refs -------------------------

    def cache_ref(self, block: int) -> None:
        assert block != SENTINEL and self._ref[block] > 0
        assert block not in self._cache_held, f"block {block} double-cached"
        self._ref[block] += 1
        self._cache_held.add(block)

    def cache_unref(self, block: int) -> bool:
        """Drop the prefix cache's reference; True if the block freed."""
        assert block in self._cache_held
        self._cache_held.remove(block)
        self._ref[block] -= 1
        if self._ref[block] == 0:
            self._free.append(block)
            return True
        return False

    def cache_only(self, block: int) -> bool:
        """True when the prefix cache is the block's sole holder — the
        eviction candidates."""
        return block in self._cache_held and self._ref[block] == 1

    # ------------------------------- CoW ------------------------------------

    def copy_on_write(self, rid: int, logical: int) -> tuple[int, int] | None:
        """Make ``rid``'s ``logical``-th block exclusively writable.

        If the block is shared (refcount > 1 — other tables and/or the
        prefix cache still map it), allocate a fresh block, swap it into
        ``rid``'s table, and return ``(src, dst)`` so the caller can issue
        the device-side block copy (``lm.copy_paged_block``).  Returns
        ``None`` when the block is already exclusive (no copy needed).

        The serving engine's admission policy (cap prefix reuse at
        ``(len-1) // block_size`` full blocks) keeps decode writes out of
        shared blocks, so serving never hits this path today; it is the
        primitive a fork/beam-search frontend needs (DESIGN.md §3b).
        """
        table = self._owned[rid]
        src = table[logical]
        assert src != SENTINEL and self._ref[src] >= 1
        if self._ref[src] == 1:
            return None
        if not self._free:
            raise MemoryError(f"pool exhausted during CoW for request {rid}")
        dst = self._free.pop()
        assert self._ref[dst] == 0
        self._ref[dst] = 1
        self._ref[src] -= 1
        table[logical] = dst
        self.n_cow += 1
        self.watermark = max(self.watermark, self.in_use())
        return src, dst

    # ---------------------------- observability -----------------------------

    def stats(self) -> dict:
        return {
            "pool_blocks": self.n_blocks,
            "block_size": self.block_size,
            "blocks_in_use": self.in_use(),
            "blocks_in_use_watermark": self.watermark,
            "blocks_cache_held": len(self._cache_held),
            "n_block_allocs": self.n_allocs,
            "n_cow_copies": self.n_cow,
        }

    def check_balanced(self, n_live_requests: int = 0) -> None:
        """Pool invariants: every block is free xor referenced, the free
        list carries no duplicates, and with no live requests every in-use
        block is held by the prefix cache alone (refcount exactly 1)."""
        assert len(set(self._free)) == len(self._free), "free-list duplicate"
        assert SENTINEL not in self._free, "sentinel escaped into free list"
        free = set(self._free)
        for b in range(1, self.n_blocks):
            if b in free:
                assert self._ref[b] == 0, f"free block {b} has refs"
            else:
                assert self._ref[b] > 0, f"leaked block {b} (no refs, not free)"
        if n_live_requests == 0:
            assert not self._owned, f"stale ownership: {sorted(self._owned)}"
            for b in range(1, self.n_blocks):
                if b not in free:
                    assert b in self._cache_held and self._ref[b] == 1, (
                        f"block {b} in use with no owner (ref={self._ref[b]})"
                    )
        # NOTE: cache references are dropped via PrefixCache.evict_lru /
        # PrefixCache.flush ONLY — map entries and pool refs must fall
        # together, or a freed-then-reallocated block could serve a stale
        # prefix hit.
