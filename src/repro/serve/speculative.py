"""Speculative decoding: a cheap KAN drafter + fused batch verification.

Decode is the regime where the paper's kernels are starved: one token per
step means ``rows = B`` — the memory-bound sparse-path regime (DESIGN.md
§2a).  Speculative decoding converts ``k`` sequential target decode steps
into (a) ``k`` steps of a much cheaper *drafter* and (b) ONE verification
pass scoring all ``W = k + 1`` window positions — batch-shaped work
(``rows = B·W``) that resolves to the fused KAN kernel on TPU
(``KL.resolve_inference_method``).  The drafter here is a *shrunken KAN*:
the first ``draft_layers`` repeats of the target's own scanned unit
(parameter slices — no second checkpoint), optionally int8 fake-quantized
(KANtize: KANs tolerate aggressive low-bit compression).

Determinism contract (the engine's bit-identity invariant, PR 3): at window
position ``j`` the verifier samples the *target* token ``t_j`` from the
target logits with the request's OWN chain key ``kt_j`` — the exact key the
sequential engine would use for that emission — and accepts the drafter's
``d_j`` iff ``d_j == t_j``.  The emitted stream is therefore always the
target chain's samples (greedy: argmax; temperature > 0: the same
per-row ``categorical`` draws), so speculative output is bit-identical to
non-speculative output *by construction*; drafter quality moves only the
acceptance rate (throughput), never a token.  This is the exact-match
specialization of standard rejection sampling: for temperature > 0 it
keeps the target distribution trivially (the emissions ARE target samples)
at the cost of rejecting token-equal-but-differently-sampled proposals —
the price of bitwise reproducibility across ``spec_k`` settings.

Cache lockstep (DESIGN.md §9): the drafter keeps its own small dense cache
``(slots, max_seq)`` over ``draft_layers`` layers.  The draft loop writes
``tok, d_0..d_{k-1}`` at ``pos..pos+k-1``; positions up to ``pos' - 1``
(the accepted prefix) hold exactly the emitted stream's KV, and garbage
beyond is overwritten by the next window before any causal mask can expose
it — the same rollback-free argument the target cache uses.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import lm


def sample_tokens(
    logits: jax.Array, step_keys: jax.Array, temperature: float
) -> jax.Array:
    """Per-row sampling: ``logits (R, vocab)``, ``step_keys (R, 2)`` — one
    key per row.  THE sampling definition shared by the sequential engine,
    the draft loop, and the verifier: per-row vmap makes each row's draw a
    pure function of (its key, its logits), so the same row samples the
    same token at any batch shape — the property the acceptance rule's
    bit-identity argument stands on."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.vmap(
        lambda k, lg: jax.random.categorical(k, lg / temperature)
    )(step_keys, logits).astype(jnp.int32)


def split_chain(keys: jax.Array, n: int) -> tuple[jax.Array, jax.Array]:
    """Advance every row's PRNG chain ``n`` steps up front.

    ``keys (B, 2)`` -> ``(kts (B, n, 2), chains (B, n + 1, 2))`` where
    ``kts[:, j]`` is the sampling key of the chain's ``j``-th split and
    ``chains[:, j]`` is the carry after ``j`` splits (``chains[:, 0] ==
    keys``).  Matches the sequential body — ``pairs = vmap(split)(keys);
    keys, kt = pairs[:, 0], pairs[:, 1]`` — split for split, so a window
    that emits ``m`` tokens resumes from ``chains[:, m]`` holding exactly
    the key the sequential engine would carry (key splitting is integer
    hashing — no float reassociation to worry about)."""

    def step(carry, _):
        pairs = jax.vmap(jax.random.split)(carry)
        return pairs[:, 0], (pairs[:, 1], pairs[:, 0])

    _, (kts, tails) = jax.lax.scan(step, keys, None, length=n)
    chains = jnp.concatenate([keys[None], tails], axis=0)   # (n+1, B, 2)
    return jnp.swapaxes(kts, 0, 1), jnp.swapaxes(chains, 0, 1)


def accept_window(
    draft: jax.Array,       # (B, k) drafter proposals
    target: jax.Array,      # (B, k+1) target-chain samples t_0..t_k
    eos_hit: jax.Array,     # (B,) latched rows emit nothing
    eos_id,                 # traced scalar; -1 never matches
    pad_id,                 # traced scalar
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Longest-matching-prefix acceptance with EOS latching.

    Window position ``j`` emits iff every prior draft matched
    (``j <= n_acc`` — the bonus token ``t_{n_acc}`` always rides along), no
    earlier window position emitted EOS, and the row wasn't already
    latched.  Returns ``(emitted (B, W), m (B,), eos_new (B,))``:
    ``emitted[:, :m]`` is the (contiguous) accepted stream — always a run
    of target-chain samples, possibly ending in EOS — and positions
    ``>= m`` carry ``pad_id``.  The sequential engine emits exactly the
    same tokens: it too keeps sampling the target chain until EOS/budget,
    and its post-EOS pads match our padding (``finalize`` pads outputs to
    budget either way)."""
    k = draft.shape[1]
    W = k + 1
    match = (draft == target[:, :k]).astype(jnp.int32)
    n_acc = jnp.cumprod(match, axis=1).sum(axis=1)          # leading matches
    j = jnp.arange(W)[None, :]
    in_prefix = j <= n_acc[:, None]                         # (B, W)
    is_eos = (target == eos_id) & in_prefix
    eos_cum = jnp.cumsum(is_eos.astype(jnp.int32), axis=1)
    eos_before = (eos_cum - is_eos.astype(jnp.int32)) > 0   # strictly earlier
    real = in_prefix & ~eos_before & ~eos_hit[:, None]
    m = real.sum(axis=1).astype(jnp.int32)
    emitted = jnp.where(real, target, pad_id)
    eos_new = eos_hit | (real & (target == eos_id)).any(axis=1)
    return emitted, m, eos_new


def draft_propose(
    dparams: dict,
    dcfg,                    # drafter ModelConfig
    k: int,                  # static: proposals per window
    tok: jax.Array,          # (B, 1) last emitted token
    caches: dict,            # drafter dense caches (slots, max_seq, ...)
    pos: jax.Array,          # (B,) window start positions
    keys: jax.Array,         # (B, 2) the request chain (NOT consumed here)
    eos_hit: jax.Array,      # (B,) latched rows freeze position
    temperature: float,
    compute_dtype,
    shard=None,
) -> tuple[jax.Array, dict]:
    """Propose ``k`` tokens per row: a fixed-shape scan of drafter decode
    steps, sampling with the SAME chain keys the verifier will use for the
    target — when drafter logits agree with target logits (argmax, or the
    categorical draw under a shared key), the proposal matches and is
    accepted.  The chain itself is not consumed: the verifier re-derives it
    and advances the carry by exactly the number of emissions.  Latched
    rows keep their position frozen (they only overwrite their own dead
    slot).  Returns ``(draft (B, k) int32, caches)``."""
    kts, _ = split_chain(keys, k)                            # (B, k, 2)

    def body(carry, kt):
        tok_c, caches_c, pos_c = carry
        lg, caches_c = lm.decode_step(
            dparams, dcfg, tok_c, caches_c, pos_c, compute_dtype, None, shard
        )
        nxt = sample_tokens(lg, kt, temperature)
        pos_c = jnp.where(eos_hit, pos_c, pos_c + 1)
        return (nxt[:, None], caches_c, pos_c), nxt

    (_, caches, _), drafts = jax.lax.scan(
        body, (tok, caches, pos), jnp.swapaxes(kts, 0, 1)
    )
    return jnp.swapaxes(drafts, 0, 1), caches                # (B, k)


def _fake_quant_int8(a: jax.Array) -> jax.Array:
    """Symmetric per-output-channel int8 round-trip (KANtize-style weight
    compression for the drafter).  Values are stored back in the original
    dtype — the CPU-honest stand-in for an int8 weight store; an actual
    int8 GEMM is a kernels/ concern.  Drafter numerics only ever move the
    acceptance rate, never an emitted token, so this needs no error
    budget."""
    if a.ndim < 2 or not jnp.issubdtype(a.dtype, jnp.floating):
        return a
    scale = jnp.maximum(
        jnp.max(jnp.abs(a.astype(jnp.float32)), axis=-1, keepdims=True), 1e-8
    ) / 127.0
    q = jnp.clip(jnp.round(a.astype(jnp.float32) / scale), -127, 127)
    return (q * scale).astype(a.dtype)


@dataclasses.dataclass
class DraftModel:
    """A drafter derived from (or supplied alongside) the target checkpoint.

    ``from_target`` builds the shrunken-KAN drafter: the first
    ``n_layers`` repeats of the target's scanned unit — parameter *slices*
    of the stacked unit leaves, so the drafter shares every non-unit tensor
    (embed/unembed, final_ln, prologue/epilogue) with the target by
    aliasing and adds only ``n_layers / n_repeats`` of the unit weights
    when quantization is off.  Its dense KV cache costs
    ``n_layers / n_repeats`` of one dense target cache — the HBM price of
    speculation (DESIGN.md §9)."""

    params: dict
    cfg: object              # drafter ModelConfig
    n_layers: int
    quant: bool = False

    @classmethod
    def from_target(cls, params: dict, cfg, n_layers: int = 1,
                    quant: bool = False) -> "DraftModel":
        if not (1 <= n_layers <= cfg.n_repeats):
            raise ValueError(
                f"draft_layers must be in [1, {cfg.n_repeats}], got {n_layers}"
            )
        if not lm.model_supports_speculative(cfg):
            raise NotImplementedError(
                f"{cfg.name}: speculative drafter needs token-input "
                "full-attention GQA blocks throughout"
            )
        dcfg = dataclasses.replace(
            cfg, name=f"{cfg.name}-draft{n_layers}", n_repeats=n_layers
        )
        dparams = dict(params)                  # alias non-unit leaves
        unit = [
            jax.tree.map(lambda a: a[:n_layers], blk_params)
            for blk_params in params["unit"]
        ]
        if quant:
            unit = jax.tree.map(_fake_quant_int8, unit)
        dparams["unit"] = unit
        return cls(params=dparams, cfg=dcfg, n_layers=n_layers, quant=quant)
