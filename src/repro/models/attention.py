"""Attention: GQA/MQA, MLA (DeepSeek-V2), sliding-window, flash-style
chunked softmax, and cached decode steps.

The training/prefill path uses a memory-efficient blockwise attention
(online softmax over KV chunks under ``lax.scan``) so 32k-token prefill
compiles with bounded live memory — no TPU kernel required for the dry-run
(and cost_analysis stays complete; see DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import ParamCtx

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    window: int | None = None        # sliding-window size (gemma3 local layers)
    rope_theta: float = 10000.0
    qk_norm: bool = False            # gemma3-style RMS q/k norm
    # MLA (deepseek-v2): when kv_lora_rank is set, K/V come from a shared
    # compressed latent that is also what the serving cache stores.
    kv_lora_rank: int | None = None
    qk_rope_dim: int = 64
    # Serving-memory features (DESIGN.md §4):
    # * windowed layers allocate a RING BUFFER of `window` slots instead of
    #   max_seq (gemma3 local layers: 32x cache cut at 32k);
    # * kv_quant stores the cache in int8 with a per-(token, kv-head) fp32
    #   scale (2x over bf16; what makes qwen1.5-32b decode_32k fit 16 GB).
    kv_quant: bool = False
    # Sequence-parallel attention (SecPerf iteration 5, prefill/train): shard
    # the QUERY sequence over the given spec (e.g. (("data",), "model", None,
    # None)) and replicate K/V over the model axis. The right call when
    # heads/kv_heads cannot shard (paligemma MQA: kv=1, 8 heads vs model=16)
    # — each shard attends its query block against full (tiny) KV instead of
    # all-reducing (B,H,T,T) score partials.
    sp_spec: tuple | None = None

    def cache_len(self, max_seq: int) -> int:
        return min(max_seq, self.window) if self.window else max_seq


def attn_init(ctx: ParamCtx, cfg: AttnConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.kv_lora_rank is None:
        p = {
            "wq": ctx.make((d, h, hd), ("embed", "heads", "head_dim")),
            "wk": ctx.make((d, kv, hd), ("embed", "kv_heads", "head_dim")),
            "wv": ctx.make((d, kv, hd), ("embed", "kv_heads", "head_dim")),
            "wo": ctx.make((h, hd, d), ("heads", "head_dim", "embed")),
        }
        if cfg.qkv_bias:
            p["bq"] = ctx.make((h, hd), ("heads", "head_dim"), init="zeros")
            p["bk"] = ctx.make((kv, hd), ("kv_heads", "head_dim"), init="zeros")
            p["bv"] = ctx.make((kv, hd), ("kv_heads", "head_dim"), init="zeros")
    else:
        r, rope = cfg.kv_lora_rank, cfg.qk_rope_dim
        nope = hd  # qk_nope dim == head_dim (v_head_dim == head_dim too)
        p = {
            "wq": ctx.make((d, h, nope + rope), ("embed", "heads", "head_dim")),
            "w_dkv": ctx.make((d, r + rope), ("embed", "kv_lora")),
            "w_uk": ctx.make((r, h, nope), ("kv_lora", "heads", "head_dim")),
            "w_uv": ctx.make((r, h, hd), ("kv_lora", "heads", "head_dim")),
            "wo": ctx.make((h, hd, d), ("heads", "head_dim", "embed")),
            "kv_norm": ctx.make((r,), ("kv_lora",), init="ones"),
        }
    if cfg.qk_norm:
        p["q_norm"] = ctx.make((hd,), ("head_dim",), init="ones")
        p["k_norm"] = ctx.make((hd,), ("head_dim",), init="ones")
    return p


def _qk_rmsnorm(x: jax.Array, scale: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention.
# ---------------------------------------------------------------------------


def flash_attention(
    q: jax.Array,                  # (B, Tq, H, D)
    k: jax.Array,                  # (B, Tk, KV, D)
    v: jax.Array,                  # (B, Tk, KV, D)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,             # absolute position of q[0] (decode/prefill)
    chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention over KV chunks; O(Tq·chunk) live memory.

    K and V may have different head dims (MLA: K carries nope+rope, V not).
    """
    B, Tq, H, D = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    rep = H // KV
    scale = 1.0 / math.sqrt(D)
    chunk = min(chunk, Tk)
    n_chunks = math.ceil(Tk / chunk)
    pad = n_chunks * chunk - Tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, KV, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KV, Dv).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(Tq)
    qh = q.reshape(B, Tq, KV, rep, D)

    def step(carry, inp):
        m, l, acc = carry
        ci, (kb, vb) = inp
        kv_pos = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("btgrd,bsgd->bgrts", qh, kb) * scale   # (B,KV,rep,Tq,chunk)
        mask = kv_pos[None, :] <= Tk - 1  # drop padded keys
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None, None], s.astype(jnp.float32), NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bgrts,bsgd->bgrtd", p.astype(vb.dtype), vb
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, rep, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, rep, Tq), jnp.float32)
    a0 = jnp.zeros((B, KV, rep, Tq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(n_chunks), (kc, vc))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Tq, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention blocks (projections + rotary + flash) and decode steps.
# ---------------------------------------------------------------------------


def _project_qkv(
    params: dict, cfg: AttnConfig, x: jax.Array, positions: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """QKV projection + bias + q/k norm + rotary at absolute ``positions``
    — the per-position math shared by full prefill (:func:`attn_forward`),
    paged suffix prefill (:func:`attn_prefill_paged`) and decode steps.
    One definition is what makes the three paths agree bit-for-bit on every
    K/V value (the paged bit-identity contract, DESIGN.md §3b)."""
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = _qk_rmsnorm(q, params["q_norm"])
        k = _qk_rmsnorm(k, params["k_norm"])
    cos, sin = L.rotary_embedding(positions, cfg.head_dim, cfg.rope_theta, x.dtype)
    q = L.apply_rotary(q, cos, sin)
    k = L.apply_rotary(k, cos, sin)
    return q, k, v


def attn_forward(
    params: dict,
    cfg: AttnConfig,
    x: jax.Array,                   # (B, T, d_model)
    *,
    positions: jax.Array | None = None,
    chunk: int = 1024,
    return_cache: bool = False,
):
    """Training/prefill attention. With ``return_cache``, also returns the
    post-rotary K/V (or the MLA latent) — exactly what the decode cache
    stores, so prefill fills caches for free."""
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.arange(T)[None, :]
    if cfg.kv_lora_rank is not None:
        return _mla_forward(params, cfg, x, positions, chunk, return_cache)
    q, k, v = _project_qkv(params, cfg, x, positions)
    if cfg.sp_spec is not None:
        from jax.sharding import PartitionSpec as _P

        bspec = cfg.sp_spec[0]
        q = jax.lax.with_sharding_constraint(q, _P(*cfg.sp_spec))
        k = jax.lax.with_sharding_constraint(k, _P(bspec, None, None, None))
        v = jax.lax.with_sharding_constraint(v, _P(bspec, None, None, None))
    o = flash_attention(q, k, v, causal=True, window=cfg.window, chunk=chunk)
    if cfg.sp_spec is not None:
        from jax.sharding import PartitionSpec as _P

        o = jax.lax.with_sharding_constraint(o, _P(*cfg.sp_spec))
    y = jnp.einsum("bthk,hkd->btd", o, params["wo"].astype(x.dtype))
    if return_cache:
        return y, {"k": k, "v": v}
    return y


def _mla_forward(params, cfg: AttnConfig, x, positions, chunk, return_cache=False):
    """DeepSeek-V2 Multi-head Latent Attention (training/prefill)."""
    B, T, _ = x.shape
    hd, rope = cfg.head_dim, cfg.qk_rope_dim
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(x.dtype))
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    ckv = x @ params["w_dkv"].astype(x.dtype)          # (B, T, r + rope)
    c_kv, k_rope_raw = ckv[..., : cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank:]
    c_kv = _qk_rmsnorm(c_kv, params["kv_norm"])
    k_nope = jnp.einsum("btr,rhk->bthk", c_kv, params["w_uk"].astype(x.dtype))
    v = jnp.einsum("btr,rhk->bthk", c_kv, params["w_uv"].astype(x.dtype))
    cos, sin = L.rotary_embedding(positions, rope, cfg.rope_theta, x.dtype)
    q_rope = L.apply_rotary(q_rope, cos, sin)
    k_rope = L.apply_rotary(k_rope_raw[..., None, :], cos, sin)
    k_rope1 = k_rope[..., 0, :]                        # (B, T, rope), shared
    k_rope = jnp.broadcast_to(k_rope, k_nope.shape[:-1] + (rope,))
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    k_full = jnp.concatenate([k_nope, k_rope], -1)
    o = flash_attention(q_full, k_full, v, causal=True, chunk=chunk)
    y = jnp.einsum("bthk,hkd->btd", o, params["wo"].astype(x.dtype))
    if return_cache:
        # cache stores the *unnormalised* latent + rotated rope key, matching
        # mla_decode_step's layout
        ckv_cache = jnp.concatenate([ckv[..., : cfg.kv_lora_rank], k_rope1], -1)
        return y, {"ckv": ckv_cache}
    return y


def _kv_quantize(k: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(..., KV, D) -> int8 values + per-(..., KV) fp32 scale."""
    scale = jnp.maximum(jnp.max(jnp.abs(k.astype(jnp.float32)), -1), 1e-6) / 127.0
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _write_cache(cache: dict, name: str, val: jax.Array, slot: jax.Array, quant: bool):
    """Write one token's K or V into the (ring) cache at ``slot``.

    Two regimes (EXPERIMENTS.md §Perf iterations 1 & 4):

    * **synchronized decode** (scalar ``slot`` — every sequence at the same
      position, the common serving case): ``dynamic_update_slice`` — GSPMD
      keeps it fully local under any cache sharding (no collectives);
    * **ragged decode** (per-batch ``slot``, continuous batching): indexed
      scatter. GSPMD's scatter partitioning all-gathers a batch-sharded
      operand (measured: 7.06 GB/step on gemma3 decode_32k), so ragged mode
      costs collectives — the engine uses synchronized buckets by default.

    Both replace the original masked-arithmetic update, which materialised
    two cache-sized temporaries (+13 GB/device on qwen1.5-32b decode_32k).
    """
    sync = slot.ndim == 0
    if quant:
        qv, sc = _kv_quantize(val)                            # (B,1,KV,D)
        if sync:
            cache[name] = jax.lax.dynamic_update_slice_in_dim(
                cache[name], qv, slot, axis=1)
            cache[name + "_scale"] = jax.lax.dynamic_update_slice_in_dim(
                cache[name + "_scale"], sc, slot, axis=1)
        else:
            b_idx = jnp.arange(val.shape[0])
            cache[name] = cache[name].at[b_idx, slot].set(qv[:, 0])
            cache[name + "_scale"] = cache[name + "_scale"].at[b_idx, slot].set(sc[:, 0])
    else:
        v = val.astype(cache[name].dtype)
        if sync:
            cache[name] = jax.lax.dynamic_update_slice_in_dim(
                cache[name], v, slot, axis=1)
        else:
            b_idx = jnp.arange(val.shape[0])
            cache[name] = cache[name].at[b_idx, slot].set(v[:, 0])
    return cache


# Logical axes of each GQA cache-dict leaf, for with_sharding_constraint
# under an optional ShardingCtx (``repro.dist.sharding``, duck-typed so the
# models package stays import-free of the dist package): dense rows (and
# gathered paged VIEWS) carry (batch, seq_cache, ...), pools carry
# (kv_blocks, ...) — the same names ``blocks.block_cache_axes``/
# ``block_paged_cache_axes`` export.  ``models/lm.py`` reuses these tables
# for its pool/view constraints — ONE definition per layout.
DENSE_CACHE_AXES = {
    "k": ("batch", "seq_cache", "kv_heads", "head_dim"),
    "v": ("batch", "seq_cache", "kv_heads", "head_dim"),
    "k_scale": ("batch", "seq_cache", "kv_heads"),
    "v_scale": ("batch", "seq_cache", "kv_heads"),
}
POOL_CACHE_AXES = {
    "k": ("kv_blocks", None, "kv_heads", "head_dim"),
    "v": ("kv_blocks", None, "kv_heads", "head_dim"),
    "k_scale": ("kv_blocks", None, "kv_heads"),
    "v_scale": ("kv_blocks", None, "kv_heads"),
}


def _constrain_cache(cache: dict, shard, paged: bool) -> dict:
    """Pin freshly written cache leaves to their logical-axes shardings so
    GSPMD keeps KV distributed through decode updates (no-op without a
    ``shard`` ctx, and bit-identical under a 1-device mesh)."""
    if shard is None:
        return cache
    table = POOL_CACHE_AXES if paged else DENSE_CACHE_AXES
    return {k: shard.constrain(v, table[k]) for k, v in cache.items()}


def _read_cache(cache: dict, name: str, quant: bool, dtype):
    if quant:
        return (
            cache[name].astype(jnp.float32) * cache[name + "_scale"][..., None]
        ).astype(dtype)
    return cache[name].astype(dtype)


def attn_decode_step(
    params: dict,
    cfg: AttnConfig,
    x: jax.Array,                   # (B, 1, d_model)
    cache: dict,                    # {"k","v"[, "k_scale","v_scale"]}
    pos: jax.Array,                 # (B,) current absolute position
    shard=None,                     # optional ShardingCtx (mesh serving)
) -> tuple[jax.Array, dict]:
    """One-token decode against a pre-filled KV cache.

    Windowed layers use a ring buffer: slot = pos % window. Rotary is applied
    *before* caching, so scores never need absolute slot positions; validity
    is "slot written", which is within-window by construction.

    ``pos`` may be scalar (synchronized decode — collective-free cache
    writes) or per-batch ``(B,)`` (ragged/continuous batching).  With a
    ``shard`` ctx the updated cache leaves are constraint-pinned to their
    logical-axes shardings (kv_heads on ``model``, batch on ``data``).
    """
    B = x.shape[0]
    S = cache["k"].shape[1]
    pos_b = jnp.broadcast_to(pos, (B,))          # per-batch view for masks
    q, k, v = _project_qkv(params, cfg, x, pos_b[:, None])
    slot = pos % S if cfg.window else pos
    cache = dict(cache)
    cache = _write_cache(cache, "k", k, slot, cfg.kv_quant)
    cache = _write_cache(cache, "v", v, slot, cfg.kv_quant)
    cache = _constrain_cache(cache, shard, paged=False)
    y = _cache_attend(params, cfg, x, cache, q, pos_b)
    return y, cache


def _cache_attend(
    params: dict,
    cfg: AttnConfig,
    x: jax.Array,                   # (B, 1, d_model)
    cache: dict,                    # (B, S, ...) leaves — dense OR paged view
    q: jax.Array,                   # (B, 1, H, D) post-rotary query
    pos_b: jax.Array,               # (B,)
) -> jax.Array:
    """The decode attention *read*: one-shot softmax (fp caches) or chunked
    flash-decode with fused dequant (int8 caches) over a ``(B, S, ...)``
    cache tree.  Shared verbatim by the dense contiguous cache and the
    paged path (which first materialises the logical view with the
    block-table gather) — running the identical program on bit-identical
    values is what makes paged decode bit-equal to dense decode."""
    B = x.shape[0]
    S = cache["k"].shape[1]
    H, KV, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    rep = H // KV
    qh = q.reshape(B, KV, rep, D)
    if not cfg.kv_quant:
        # One-shot attention read: decode scores are only (B,KV,rep,S) —
        # small — and a single einsum + softmax lets GSPMD run the
        # distributed-softmax pattern when the cache is seq-sharded
        # (SecPerf iteration 4). Chunking is only needed to bound the
        # dequantisation temp of int8 caches (below).
        ck = cache["k"].astype(x.dtype)
        cv = cache["v"].astype(x.dtype)
        s = jnp.einsum("bgrd,bsgd->bgrs", qh, ck) / math.sqrt(D)
        kv_slot = jnp.arange(S)[None, :]
        if cfg.window:
            mask = (kv_slot <= pos_b[:, None]) | (pos_b[:, None] >= S)
        else:
            mask = kv_slot <= pos_b[:, None]
        s = jnp.where(mask[:, None, None], s.astype(jnp.float32), NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bgrs,bsgd->bgrd", p.astype(cv.dtype), cv)
        o = o.reshape(B, 1, H, D).astype(x.dtype)
        return jnp.einsum("bthk,hkd->btd", o, params["wo"].astype(x.dtype))
    # int8 cache: flash-decode chunks bound the dequant temp
    # (EXPERIMENTS.md SecPerf iteration 1: -21 GB on qwen1.5-32b decode_32k)
    chunk = min(8192, S)
    n_chunks = (S + chunk - 1) // chunk
    assert S % chunk == 0 or n_chunks == 1, "cache length is chunk-aligned"

    def read_chunk(name, ci):
        raw = jax.lax.dynamic_slice_in_dim(cache[name], ci * chunk, chunk, 1)
        if cfg.kv_quant:
            sc = jax.lax.dynamic_slice_in_dim(
                cache[name + "_scale"], ci * chunk, chunk, 1
            )
            return (raw.astype(jnp.float32) * sc[..., None]).astype(x.dtype)
        return raw.astype(x.dtype)

    def step(carry, ci):
        m_p, l_p, acc_p = carry
        kb = read_chunk("k", ci)                              # (B,chunk,KV,D)
        vb = read_chunk("v", ci)
        s = jnp.einsum("bgrd,bsgd->bgrs", qh, kb) / math.sqrt(D)
        kv_slot = ci * chunk + jnp.arange(chunk)[None, :]
        if cfg.window:
            mask = (kv_slot <= pos_b[:, None]) | (pos_b[:, None] >= S)
        else:
            mask = kv_slot <= pos_b[:, None]
        s = jnp.where(mask[:, None, None], s.astype(jnp.float32), NEG_INF)
        m_new = jnp.maximum(m_p, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_p - m_new)
        l_new = l_p * corr + p.sum(-1)
        acc = acc_p * corr[..., None] + jnp.einsum(
            "bgrs,bsgd->bgrd", p.astype(vb.dtype), vb
        ).astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, KV, rep), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, rep), jnp.float32)
    a0 = jnp.zeros((B, KV, rep, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(n_chunks))
    o = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(x.dtype)
    o = o.reshape(B, 1, H, D)
    return jnp.einsum("bthk,hkd->btd", o, params["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Paged KV cache (DESIGN.md §3b): pool + block-table addressing.
#
# Layout: each cache leaf becomes a POOL ``(n_blocks, block_size, ...)``
# shared by all batch rows; a per-row block table ``(B, n_logical)`` maps
# logical block l of row b to a physical block (``n_logical * block_size ==
# max_seq``).  Physical block 0 is the reserved sentinel: unassigned table
# entries point at it and out-of-coverage writes are redirected into it —
# its contents are finite garbage that the causal mask annihilates exactly
# (``exp(NEG_INF - m) == 0.0`` in fp32), so reads through it can never
# perturb live rows.  The read path gathers the logical ``(B, max_seq,
# ...)`` view (Pallas block-table gather on TPU, ``jnp.take`` elsewhere —
# ``kernels/paged_gather.py``) and then runs the UNCHANGED dense math
# (:func:`_cache_attend` / :func:`flash_attention`), which is what makes
# paged serving bit-identical to the dense contiguous cache.
# ---------------------------------------------------------------------------


def paged_view(cache: dict, table: jax.Array) -> dict:
    """Materialise the logical contiguous view of a paged cache tree:
    pools ``(n_blocks, bs, ...)`` + table ``(B, L)`` -> ``(B, L·bs, ...)``
    leaves, gathered with the block-table kernel."""
    from repro.kernels.paged_gather import gather_blocks

    return {name: gather_blocks(pool, table) for name, pool in cache.items()}


def paged_route(
    table: jax.Array,               # (B, L) block table
    positions: jax.Array,           # (B, T) absolute cache positions
    block_size: int,
    valid: jax.Array | None = None, # extra (B, T) mask (e.g. pad positions)
) -> tuple[jax.Array, jax.Array]:
    """THE block-table write routing: absolute positions -> ``(phys, off)``
    scatter targets.  Positions past the table span — and any caller-masked
    positions — are redirected to the sentinel block 0.  Every paged write
    path (per-token, prefill span, shadow-chunk writeback) routes through
    this one definition, because the sentinel-redirect invariant is what
    the paged bit-identity contract stands on."""
    L = table.shape[1]
    lb = jnp.minimum(positions // block_size, L - 1)
    ok = positions < L * block_size
    if valid is not None:
        ok = ok & valid
    phys = jnp.where(ok, jnp.take_along_axis(table, lb, axis=1), 0)
    return phys, positions % block_size


def _paged_write_token(
    cache: dict, name: str, val: jax.Array, table: jax.Array,
    pos_b: jax.Array, quant: bool,
) -> dict:
    """Write one decode token's K or V into its pool block: the T=1 case of
    :func:`paged_write_span` (per-row start ``pos_b``, every position
    real).  ``lengths = pos_b + 1`` makes the span's pad mask vacuous while
    keeping its out-of-coverage sentinel redirect — one definition of the
    write routing the bit-identity contract depends on."""
    return paged_write_span(cache, name, val, table, pos_b, pos_b + 1, quant)


def paged_write_span(
    cache: dict, name: str, val: jax.Array, table: jax.Array,
    start: jax.Array, lengths: jax.Array, quant: bool,
) -> dict:
    """Scatter a span of K or V into pool blocks.

    ``val (B, T, KV, D)`` holds positions ``start + t`` (``start`` scalar —
    grouped admission prefill — or per-row ``(B,)`` — decode steps); rows
    are right-padded — positions ``>= lengths[b]`` are redirected to the
    sentinel block so pad K/V never lands in a real block (the dense path
    keeps pad KV in its private row, where causality hides it; a shared
    pool has no private rows, so pads must be discarded at write time).
    The same redirect absorbs positions past the table span: fixed-shape
    chunks overrun finished rows, and retired slots' table rows are reset
    to sentinel — duplicate sentinel writes are unordered but the sentinel
    is never attendable.
    """
    pool = cache[name]
    B, T = val.shape[:2]
    bs = pool.shape[1]
    starts = jnp.reshape(jnp.asarray(start), (-1, 1))      # scalar or (B,)
    positions = jnp.broadcast_to(starts + jnp.arange(T)[None, :], (B, T))
    phys, off = paged_route(table, positions, bs,
                            valid=positions < lengths[:, None])
    if quant:
        qv, sc = _kv_quantize(val)
        cache[name] = pool.at[phys, off].set(qv)
        cache[name + "_scale"] = cache[name + "_scale"].at[phys, off].set(sc)
    else:
        cache[name] = pool.at[phys, off].set(val.astype(pool.dtype))
    return cache


def attn_decode_step_paged(
    params: dict,
    cfg: AttnConfig,
    x: jax.Array,                   # (B, 1, d_model)
    cache: dict,                    # POOL leaves (n_blocks, bs, ...)
    table: jax.Array,               # (B, n_logical) int32 block table
    pos: jax.Array,                 # (B,) absolute positions
    shard=None,                     # optional ShardingCtx (mesh serving)
) -> tuple[jax.Array, dict]:
    """One-token decode against the paged pool: identical QKV math, writes
    routed through the block table, then :func:`_cache_attend` on the
    gathered logical view — bit-identical to :func:`attn_decode_step` on
    the dense contiguous cache (tested in ``tests/test_kv_pool.py``)."""
    assert cfg.window is None and cfg.kv_lora_rank is None, (
        "paged KV supports full-attention GQA layers only"
    )
    B = x.shape[0]
    pos_b = jnp.broadcast_to(pos, (B,))
    q, k, v = _project_qkv(params, cfg, x, pos_b[:, None])
    cache = dict(cache)
    cache = _paged_write_token(cache, "k", k, table, pos_b, cfg.kv_quant)
    cache = _paged_write_token(cache, "v", v, table, pos_b, cfg.kv_quant)
    cache = _constrain_cache(cache, shard, paged=True)
    y = _cache_attend(params, cfg, x, paged_view(cache, table), q, pos_b)
    return y, cache


def attn_prefill_paged(
    params: dict,
    cfg: AttnConfig,
    x: jax.Array,                   # (B, Ts, d_model) — the UNCACHED suffix
    positions: jax.Array,           # (B, Ts) absolute positions (start + t)
    cache: dict,                    # POOL leaves (n_blocks, bs, ...)
    table: jax.Array,               # (B, n_logical)
    lengths: jax.Array,             # (B,) true total prompt lengths
    start: jax.Array,               # scalar: first uncached position
    chunk: int = 1024,
    view_blocks: int | None = None, # static: table columns the attention
                                    # view needs (covers start + T); None =
                                    # all (the full max_seq view)
    shard=None,                     # optional ShardingCtx (mesh serving)
) -> tuple[jax.Array, dict]:
    """Suffix prefill into pool blocks: the prefix-cache hit path computes
    only positions ``start..len-1`` (a prefix hit makes ``start > 0``).

    Attention runs over the logical view with the freshly computed span
    **overlaid raw** (``dynamic_update_slice`` at ``start``): positions
    ``< start`` come from reused blocks (bit-equal to a full prefill's
    values by induction), the suffix attends its own raw K/V exactly as a
    full prefill would — including under ``kv_quant``, where the pool
    stores int8 but prefill attention must see raw values to stay
    bit-identical to the dense path (which only quantizes at cache-store
    time).  Chunks beyond a query's causal range are exact no-ops in the
    online softmax (``corr == exp(0) == 1``), so the view's ``max_seq``
    length vs. the dense path's padded prompt length cannot change a single
    bit.
    """
    assert cfg.window is None and cfg.kv_lora_rank is None, (
        "paged KV supports full-attention GQA layers only"
    )
    q, k, v = _project_qkv(params, cfg, x, positions)
    cache = dict(cache)
    cache = paged_write_span(cache, "k", k, table, start, lengths, cfg.kv_quant)
    cache = paged_write_span(cache, "v", v, table, start, lengths, cfg.kv_quant)
    cache = _constrain_cache(cache, shard, paged=True)
    # The view only needs the causally reachable range (<= start + T): any
    # chunk past the last query position is an exact online-softmax no-op,
    # so truncating to a static block count changes no bits but cuts the
    # flash sweep from max_seq to ~the padded prompt length — the same
    # work the dense prefill does.
    view = paged_view(cache, table if view_blocks is None
                      else table[:, :view_blocks])
    ck = _read_cache(view, "k", cfg.kv_quant, x.dtype)
    cv = _read_cache(view, "v", cfg.kv_quant, x.dtype)
    ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), start, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), start, axis=1)
    o = flash_attention(q, ck, cv, causal=True, q_offset=start, chunk=chunk)
    y = jnp.einsum("bthk,hkd->btd", o, params["wo"].astype(x.dtype))
    return y, cache


# ---------------------------------------------------------------------------
# Speculative verify windows (DESIGN.md §9): score W = k+1 draft positions in
# ONE batch-shaped pass against the live decode cache.  The whole point of
# speculative decoding here is shape conversion — k sequential decode steps
# (rows = B, the sparse/memory-bound regime) become one pass with B·W rows
# (the fused-kernel regime) — so these entry points must NOT be a scan of
# decode steps.  Bit-identity with the sequential path instead rests on the
# same per-element-reduction argument the batch dimension already relies on:
# the window axis ``t`` is carried as a pure batch axis through every einsum
# (contractions stay over ``d`` / ``s`` with identical per-element lengths),
# so position j of a window computes exactly the arrays decode step j would.
# ---------------------------------------------------------------------------


def _write_cache_span(
    cache: dict, name: str, val: jax.Array, positions: jax.Array, quant: bool
) -> dict:
    """Scatter a (B, W) span of K or V into a dense ``(B, S, ...)`` cache at
    per-row absolute ``positions``.  The W-token generalisation of
    :func:`_write_cache`'s ragged branch; positions ``>= S`` drop (jax
    scatter out-of-bounds semantics), mirroring the sentinel redirect of the
    paged span write — fixed-shape windows may overrun ``max_seq`` on rows
    that retire this window."""
    b_idx = jnp.arange(val.shape[0])[:, None]
    if quant:
        qv, sc = _kv_quantize(val)                            # (B,W,KV,D)
        cache[name] = cache[name].at[b_idx, positions].set(qv)
        cache[name + "_scale"] = (
            cache[name + "_scale"].at[b_idx, positions].set(sc)
        )
    else:
        cache[name] = cache[name].at[b_idx, positions].set(
            val.astype(cache[name].dtype)
        )
    return cache


def _cache_attend_window(
    params: dict,
    cfg: AttnConfig,
    x: jax.Array,                   # (B, W, d_model)
    cache: dict,                    # (B, S, ...) leaves — dense OR paged view
    q: jax.Array,                   # (B, W, H, D) post-rotary queries
    pos_b: jax.Array,               # (B,) window start positions
) -> jax.Array:
    """The verify-window attention *read*: :func:`_cache_attend` with the
    window axis rode along as a batch axis.  Query j (absolute position
    ``pos_b + j``) masks ``kv_slot <= pos_b + j`` — its own freshly written
    slot included, exactly like the sequential step — and every reduction
    (q·k over ``d``, softmax over ``S``, p·v over ``s``) keeps the decode
    path's per-element operand length, so each window position reproduces
    the sequential step's bits."""
    B, W = q.shape[:2]
    S = cache["k"].shape[1]
    H, KV, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    rep = H // KV
    qh = q.reshape(B, W, KV, rep, D)
    q_pos = pos_b[:, None] + jnp.arange(W)[None, :]           # (B, W)
    if not cfg.kv_quant:
        ck = cache["k"].astype(x.dtype)
        cv = cache["v"].astype(x.dtype)
        s = jnp.einsum("btgrd,bsgd->btgrs", qh, ck) / math.sqrt(D)
        mask = jnp.arange(S)[None, None, :] <= q_pos[:, :, None]   # (B,W,S)
        s = jnp.where(mask[:, :, None, None, :], s.astype(jnp.float32), NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("btgrs,bsgd->btgrd", p.astype(cv.dtype), cv)
        o = o.reshape(B, W, H, D).astype(x.dtype)
        return jnp.einsum("bthk,hkd->btd", o, params["wo"].astype(x.dtype))
    # int8 cache: the chunked flash-decode sweep with a W axis in the carry
    chunk = min(8192, S)
    n_chunks = (S + chunk - 1) // chunk
    assert S % chunk == 0 or n_chunks == 1, "cache length is chunk-aligned"

    def read_chunk(name, ci):
        raw = jax.lax.dynamic_slice_in_dim(cache[name], ci * chunk, chunk, 1)
        sc = jax.lax.dynamic_slice_in_dim(
            cache[name + "_scale"], ci * chunk, chunk, 1
        )
        return (raw.astype(jnp.float32) * sc[..., None]).astype(x.dtype)

    def step(carry, ci):
        m_p, l_p, acc_p = carry
        kb = read_chunk("k", ci)                              # (B,chunk,KV,D)
        vb = read_chunk("v", ci)
        s = jnp.einsum("btgrd,bsgd->btgrs", qh, kb) / math.sqrt(D)
        kv_slot = ci * chunk + jnp.arange(chunk)
        mask = kv_slot[None, None, :] <= q_pos[:, :, None]
        s = jnp.where(mask[:, :, None, None, :], s.astype(jnp.float32), NEG_INF)
        m_new = jnp.maximum(m_p, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_p - m_new)
        l_new = l_p * corr + p.sum(-1)
        acc = acc_p * corr[..., None] + jnp.einsum(
            "btgrs,bsgd->btgrd", p.astype(vb.dtype), vb
        ).astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, W, KV, rep), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, W, KV, rep), jnp.float32)
    a0 = jnp.zeros((B, W, KV, rep, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(n_chunks))
    o = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(x.dtype)
    o = o.reshape(B, W, H, D)
    return jnp.einsum("bthk,hkd->btd", o, params["wo"].astype(x.dtype))


def attn_verify_window(
    params: dict,
    cfg: AttnConfig,
    x: jax.Array,                   # (B, W, d_model) — last token + k drafts
    cache: dict,                    # dense (B, S, ...) leaves
    pos: jax.Array,                 # (B,) window start (= next write slot)
    shard=None,
) -> tuple[jax.Array, dict]:
    """W-token verify against the dense cache: write all W post-rotary K/V
    spans (quantized when ``kv_quant`` — the sequential step also attends
    its own freshly *quantized* write, so verify must too), then attend with
    per-query causal masks.  Rejected positions leave garbage K/V at slots
    ``>= pos + m``; the next window rewrites every such slot before any
    query can reach it (its start ``pos'`` satisfies ``pos' + k >= pos + k``
    and causality bounds reads at ``pos' + j``), so no rollback is needed."""
    assert cfg.window is None and cfg.kv_lora_rank is None, (
        "speculative verify supports full-attention GQA layers only"
    )
    B, W, _ = x.shape
    pos_b = jnp.broadcast_to(pos, (B,))
    positions = pos_b[:, None] + jnp.arange(W)[None, :]       # (B, W)
    q, k, v = _project_qkv(params, cfg, x, positions)
    cache = dict(cache)
    cache = _write_cache_span(cache, "k", k, positions, cfg.kv_quant)
    cache = _write_cache_span(cache, "v", v, positions, cfg.kv_quant)
    cache = _constrain_cache(cache, shard, paged=False)
    y = _cache_attend_window(params, cfg, x, cache, q, pos_b)
    return y, cache


def attn_verify_window_paged(
    params: dict,
    cfg: AttnConfig,
    x: jax.Array,                   # (B, W, d_model)
    cache: dict,                    # POOL leaves (n_blocks, bs, ...)
    table: jax.Array,               # (B, n_logical)
    pos: jax.Array,                 # (B,)
    shard=None,
) -> tuple[jax.Array, dict]:
    """W-token verify against the paged pool: span writes routed through the
    block table (admission caps prefix reuse at ``(len-1)//bs`` full blocks,
    so window writes can never land in a refcounted shared block — rejected
    tokens only dirty request-exclusive blocks, which the engine trims from
    coverage instead of CoW-copying), then the identical window attention
    on the gathered logical view."""
    assert cfg.window is None and cfg.kv_lora_rank is None, (
        "paged KV supports full-attention GQA layers only"
    )
    B, W, _ = x.shape
    pos_b = jnp.broadcast_to(pos, (B,))
    positions = pos_b[:, None] + jnp.arange(W)[None, :]
    q, k, v = _project_qkv(params, cfg, x, positions)
    cache = dict(cache)
    cache = paged_write_span(cache, "k", k, table, pos_b, pos_b + W, cfg.kv_quant)
    cache = paged_write_span(cache, "v", v, table, pos_b, pos_b + W, cfg.kv_quant)
    cache = _constrain_cache(cache, shard, paged=True)
    y = _cache_attend_window(params, cfg, x, paged_view(cache, table), q, pos_b)
    return y, cache


def mla_decode_step(
    params: dict,
    cfg: AttnConfig,
    x: jax.Array,                   # (B, 1, d_model)
    cache_ckv: jax.Array,           # (B, S, r + rope) — the compressed latent
    pos: jax.Array,
    shard=None,                     # optional ShardingCtx (mesh serving)
) -> tuple[jax.Array, jax.Array]:
    """MLA decode: the cache stores only the (r + rope)-dim latent — the
    memory win that makes DeepSeek-V2 serving cheap.  Like every other
    cache-mutating entry point (kanlint KL105), the freshly written latent
    is pinned to its logical axes under a mesh so GSPMD can't gather it."""
    B = x.shape[0]
    S = cache_ckv.shape[1]
    pos_b = jnp.broadcast_to(pos, (B,))
    r, rope, hd = cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.head_dim
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(x.dtype))
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    ckv_new = x @ params["w_dkv"].astype(x.dtype)             # (B, 1, r+rope)
    cos, sin = L.rotary_embedding(pos_b[:, None], rope, cfg.rope_theta, x.dtype)
    q_rope = L.apply_rotary(q_rope, cos, sin)
    rotated = L.apply_rotary(ckv_new[..., None, r:], cos, sin)[..., 0, :]
    ckv_new = jnp.concatenate([ckv_new[..., :r], rotated], -1)
    if pos.ndim == 0:  # synchronized decode: collective-free DUS
        cache_ckv = jax.lax.dynamic_update_slice_in_dim(
            cache_ckv, ckv_new.astype(cache_ckv.dtype), pos, axis=1)
    else:
        cache_ckv = cache_ckv.at[jnp.arange(B), pos_b].set(
            ckv_new[:, 0].astype(cache_ckv.dtype)
        )
    if shard is not None:
        cache_ckv = shard.constrain(
            cache_ckv, ("batch", "seq_cache", "kv_lora")
        )

    c_kv = _qk_rmsnorm(cache_ckv[..., :r], params["kv_norm"])  # (B, S, r)
    k_rope = cache_ckv[..., r:]                                # (B, S, rope)
    # Absorbed-weight trick: score = q_nope·(W_uk c) + q_rope·k_rope
    q_abs = jnp.einsum("bthk,rhk->bthr", q_nope, params["w_uk"].astype(x.dtype))
    s = jnp.einsum("bhr,bsr->bhs", q_abs[:, 0], c_kv)
    s = s + jnp.einsum("bhk,bsk->bhs", q_rope[:, 0], k_rope)
    s = s / math.sqrt(hd + rope)
    mask = jnp.arange(S)[None, :] <= pos_b[:, None]
    s = jnp.where(mask[:, None], s.astype(jnp.float32), NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhs,bsr->bhr", p, c_kv)                # (B, H, r)
    o = jnp.einsum("bhr,rhk->bhk", o_lat, params["w_uv"].astype(x.dtype))
    y = jnp.einsum("bhk,hkd->bd", o, params["wo"].astype(x.dtype))[:, None]
    return y, cache_ckv
