"""xLSTM blocks (mLSTM + sLSTM) — arXiv:2405.04517.

* mLSTM: matrix memory ``C : (dk, dv)`` per head with exponential gating and
  max-stabiliser; implemented as a time-step ``lax.scan`` (baseline; the
  chunked-parallel form is a §Perf optimisation — see EXPERIMENTS.md).
* sLSTM: scalar memory with block-diagonal recurrent weights; inherently
  sequential (scan).

Both keep O(1) decode state, which is why xlstm-1.3b runs the ``long_500k``
cell. The pool config specifies ``d_ff=0``: blocks carry their own
projection factor (pf=2 gate/up-down) and there is no separate FFN sublayer.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import ParamCtx


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    n_heads: int = 4
    proj_factor: int = 2
    conv_width: int = 4
    # mLSTM sequence algorithm: "recurrent" (baseline: lax.scan over time,
    # moves the (D,D) matrix state every step) or "chunked" (chunkwise
    # parallel: quadratic intra-chunk + one state update per chunk — the
    # §Perf hillclimb optimisation; state traffic drops by ~chunk x).
    mlstm_impl: str = "recurrent"
    chunk: int = 64
    # cost-faithful dry-run: unroll the chunk scan so XLA's cost_analysis
    # (which counts while bodies once) sees every chunk (launch/dryrun.py)
    scan_unroll: bool = False

    @property
    def d_inner(self) -> int:
        return self.proj_factor * self.d_model

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


# ------------------------------- mLSTM --------------------------------------


def mlstm_init(ctx: ParamCtx, cfg: XLSTMConfig) -> dict:
    d, di, H, hd = cfg.d_model, cfg.d_inner, cfg.n_heads, cfg.head_dim
    return {
        "up": ctx.make((d, 2 * di), ("embed", "ffn")),
        "conv_w": ctx.make((cfg.conv_width, di), (None, "ffn"), scale=0.5),
        "conv_b": ctx.make((di,), ("ffn",), init="zeros"),
        "wq": ctx.make((di, di), ("ffn", "heads")),
        "wk": ctx.make((di, di), ("ffn", "heads")),
        "wv": ctx.make((di, di), ("ffn", "heads")),
        "w_i": ctx.make((di, H), ("ffn", "heads"), scale=0.02),
        "w_f": ctx.make((di, H), ("ffn", "heads"), scale=0.02),
        "b_i": ctx.make((H,), ("heads",), init="zeros"),
        "b_f": ctx.make((H,), ("heads",), init="ones"),
        "norm": ctx.make((di,), ("ffn",), init="ones"),
        "down": ctx.make((di, d), ("ffn", "embed")),
    }


def _causal_conv(x, w, b):
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(W))
    return out + b.astype(x.dtype)


def _mlstm_core(q, k, v, i_pre, f_pre):
    """Stabilised recurrent mLSTM. q,k,v: (B,T,H,D); gates: (B,T,H) pre-act.

    C_t = f C_{t-1} + i v k^T ; n_t = f n + i k ; y = C^T q / max(|n·q|, 1).
    Stabiliser m_t = max(log f + m_{t-1}, log i) keeps exp() bounded.
    """
    B, T, H, D = q.shape
    scale = D ** -0.5

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, it, ft = inp                      # (B,H,D)x3, (B,H)x2
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(logf + m - m_new)
        C = f_[..., None, None] * C + i_[..., None, None] * (
            kt[..., :, None] * vt[..., None, :]
        )
        n = f_[..., None] * n + i_[..., None] * kt
        num = jnp.einsum("bhkv,bhk->bhv", C, qt * scale)
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt * scale)), jnp.exp(-m_new)
        )
        y = num / den[..., None]
        return (C, n, m_new), y

    C0 = jnp.zeros((B, H, D, D), jnp.float32)
    n0 = jnp.zeros((B, H, D), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    xs = (
        q.transpose(1, 0, 2, 3).astype(jnp.float32),
        k.transpose(1, 0, 2, 3).astype(jnp.float32),
        v.transpose(1, 0, 2, 3).astype(jnp.float32),
        i_pre.transpose(1, 0, 2).astype(jnp.float32),
        f_pre.transpose(1, 0, 2).astype(jnp.float32),
    )
    carry, ys = jax.lax.scan(step, (C0, n0, m0), xs)
    return ys.transpose(1, 0, 2, 3), carry            # (B,T,H,D), final state


def _mlstm_core_chunked(q, k, v, i_pre, f_pre, chunk: int, unroll: bool = False):
    """Chunkwise-parallel stabilised mLSTM (the §Perf optimisation).

    Identical math to :func:`_mlstm_core` — the exponential-gated linear
    recurrence unrolls to ``y_i ∝ Σ_l exp(F_i - F_l + b_l - m_i) (q_i·k_l) v_l``
    — but evaluated per chunk: a masked quadratic intra-chunk term (MXU) plus
    ONE (D, D) state read/write per chunk instead of per step, cutting the
    state HBM traffic by ~chunk x. Stabiliser ``m`` follows the same
    running-max semantics at chunk granularity.
    """
    B, T, H, D = q.shape
    scale = D ** -0.5
    nc = T // chunk
    Q_ = chunk

    def r(t):
        return t.reshape((B, nc, Q_) + t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

    qc = r(q).astype(jnp.float32) * scale       # (nc, B, Q, H, D)
    kc = r(k).astype(jnp.float32)
    vc = r(v).astype(jnp.float32)
    a = jax.nn.log_sigmoid(r(f_pre).astype(jnp.float32))   # (nc, B, Q, H)
    b = r(i_pre).astype(jnp.float32)
    F = jnp.cumsum(a, axis=2)                   # in-chunk cumulative log-forget
    F_total = F[:, :, -1, :]                    # (nc, B, H)

    # intra-chunk log-weights W[i, l] = F_i - F_l + b_l  (l <= i)
    logw = F[:, :, :, None, :] - F[:, :, None, :, :] + b[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((Q_, Q_), bool))
    logw = jnp.where(tri[None, None, :, :, None], logw, -jnp.inf)
    m_intra = logw.max(axis=3)                  # (nc, B, Q, H)

    def chunk_step(carry, inp):
        C_p, n_p, m_p = carry                   # (B,H,D,D), (B,H,D), (B,H)
        qb, kb, vb, ab, bb, Fb, Ft, lw, mi = inp
        # combined stabiliser: running max across chunks
        m_i = jnp.maximum(m_p[:, None, :] + Fb, mi)        # (B, Q, H)
        # intra: softmax-like masked quadratic
        w = jnp.exp(lw - m_i[:, :, None, :])               # (B, Qi, Ql, H)
        s = jnp.einsum("bihd,blhd->bilh", qb, kb)
        y_intra = jnp.einsum("bilh,bilh,blhd->bihd", s, w, vb)
        n_intra = jnp.einsum("bilh,blhd->bihd", w, kb)
        # inter: previous state scaled into the new stabiliser frame
        dec_i = jnp.exp(m_p[:, None, :] + Fb - m_i)        # (B, Q, H)
        y_inter = jnp.einsum("bihd,bhde->bihe", qb, C_p) * dec_i[..., None]
        n_inter = jnp.einsum("bihd,bhd->bih", qb, n_p) * dec_i
        num = y_intra + y_inter
        den_dot = jnp.einsum("bihd,bihd->bih", qb, n_intra) + n_inter
        den = jnp.maximum(jnp.abs(den_dot), jnp.exp(-m_i))
        y = num / den[..., None]
        # state update to end-of-chunk
        m_new = jnp.maximum(m_p + Ft, (Ft[:, None] - Fb + bb).max(axis=1))
        dec_l = jnp.exp(Ft[:, None, :] - Fb + bb - m_new[:, None, :])  # (B,Q,H)
        C_new = jnp.exp(m_p + Ft - m_new)[..., None, None] * C_p + jnp.einsum(
            "blh,blhd,blhe->bhde", dec_l, kb, vb
        )
        n_new = jnp.exp(m_p + Ft - m_new)[..., None] * n_p + jnp.einsum(
            "blh,blhd->bhd", dec_l, kb
        )
        return (C_new, n_new, m_new), y

    C0 = jnp.zeros((B, H, D, D), jnp.float32)
    n0 = jnp.zeros((B, H, D), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    carry, ys = jax.lax.scan(
        chunk_step, (C0, n0, m0), (qc, kc, vc, a, b, F, F_total, logw, m_intra),
        unroll=nc if unroll else 1,
    )
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, H, D)
    return y, carry


def mlstm_forward(
    params: dict, cfg: XLSTMConfig, x: jax.Array, return_state: bool = False
):
    B, T, _ = x.shape
    di, H, hd = cfg.d_inner, cfg.n_heads, cfg.head_dim
    up = x @ params["up"].astype(x.dtype)
    xm, z = jnp.split(up, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xm, params["conv_w"], params["conv_b"]))
    q = (xc @ params["wq"].astype(x.dtype)).reshape(B, T, H, hd)
    k = (xc @ params["wk"].astype(x.dtype)).reshape(B, T, H, hd)
    v = (xm @ params["wv"].astype(x.dtype)).reshape(B, T, H, hd)
    i_pre = xc @ params["w_i"].astype(x.dtype) + params["b_i"].astype(x.dtype)
    f_pre = xc @ params["w_f"].astype(x.dtype) + params["b_f"].astype(x.dtype)
    if cfg.mlstm_impl == "chunked" and T % cfg.chunk == 0 and T > cfg.chunk:
        yh, (Cf, nf, mf) = _mlstm_core_chunked(
            q, k, v, i_pre, f_pre, cfg.chunk, unroll=cfg.scan_unroll)
    else:
        yh, (Cf, nf, mf) = _mlstm_core(q, k, v, i_pre, f_pre)
    y = yh.reshape(B, T, di).astype(x.dtype)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)).astype(
        x.dtype
    ) * params["norm"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ params["down"].astype(x.dtype)
    if return_state:
        W = cfg.conv_width
        conv_state = jnp.concatenate(
            [jnp.zeros((B, max(0, W - 1 - T), di), x.dtype),
             xm[:, max(0, T - (W - 1)):]], axis=1)
        return out, {"C": Cf, "n": nf, "m": mf, "conv": conv_state}
    return out


def mlstm_init_state(cfg: XLSTMConfig, batch: int, dtype=jnp.float32) -> dict:
    H, hd = cfg.n_heads, cfg.head_dim
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), dtype),
    }


def mlstm_decode_step(params, cfg: XLSTMConfig, x, state):
    """x: (B, 1, d) -> O(1) state update."""
    B = x.shape[0]
    di, H, hd = cfg.d_inner, cfg.n_heads, cfg.head_dim
    up = x[:, 0] @ params["up"].astype(x.dtype)
    xm, z = jnp.split(up, 2, axis=-1)
    buf = jnp.concatenate([state["conv"], xm[:, None]], axis=1)
    xc = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", buf, params["conv_w"].astype(x.dtype))
        + params["conv_b"].astype(x.dtype)
    )
    q = (xc @ params["wq"].astype(x.dtype)).reshape(B, H, hd).astype(jnp.float32)
    k = (xc @ params["wk"].astype(x.dtype)).reshape(B, H, hd).astype(jnp.float32)
    v = (xm @ params["wv"].astype(x.dtype)).reshape(B, H, hd).astype(jnp.float32)
    it = (xc @ params["w_i"].astype(x.dtype) + params["b_i"].astype(x.dtype)).astype(jnp.float32)
    ft = (xc @ params["w_f"].astype(x.dtype) + params["b_f"].astype(x.dtype)).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + state["m"], it)
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(logf + state["m"] - m_new)
    C = f_[..., None, None] * state["C"] + i_[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n = f_[..., None] * state["n"] + i_[..., None] * k
    scale = hd ** -0.5
    num = jnp.einsum("bhkv,bhk->bhv", C, q * scale)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q * scale)), jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(B, di).astype(x.dtype)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)).astype(
        x.dtype
    ) * params["norm"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = y @ params["down"].astype(x.dtype)
    return y[:, None], {"C": C, "n": n, "m": m_new, "conv": buf[:, 1:]}


# ------------------------------- sLSTM --------------------------------------


def slstm_init(ctx: ParamCtx, cfg: XLSTMConfig) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    p = {"norm": ctx.make((d,), ("embed",), init="ones")}
    for g in ("z", "i", "f", "o"):
        p[f"w_{g}"] = ctx.make((d, d), ("embed", "heads"), scale=0.02)
        p[f"r_{g}"] = ctx.make((H, hd, hd), ("heads", None, None), scale=0.02)
        p[f"b_{g}"] = ctx.make((d,), ("heads",), init="ones" if g == "f" else "zeros")
    return p


def slstm_forward(
    params: dict, cfg: XLSTMConfig, x: jax.Array, return_state: bool = False
):
    """Scalar-memory LSTM with exponential gating; scan over time."""
    B, T, d = x.shape
    H = cfg.n_heads
    hd = d // H
    wz = jnp.stack([params[f"w_{g}"] for g in "zifo"]).astype(x.dtype)
    bz = jnp.stack([params[f"b_{g}"] for g in "zifo"]).astype(jnp.float32)
    rz = jnp.stack([params[f"r_{g}"] for g in "zifo"]).astype(jnp.float32)
    pre = jnp.einsum("btd,gde->btge", x, wz).astype(jnp.float32) + bz[None, None]

    def step(carry, inp):
        c, n, h, m = carry                            # (B,H,hd) x3, (B,H,hd)
        pre_t = inp                                   # (B, 4, d)
        rec = jnp.einsum("bhe,ghef->bghf", h, rz)     # (B,4,H,hd)
        tot = pre_t.reshape(B, 4, H, hd) + rec
        zt = jnp.tanh(tot[:, 0])
        it = tot[:, 1]
        ft = jax.nn.log_sigmoid(tot[:, 2])
        ot = jax.nn.sigmoid(tot[:, 3])
        m_new = jnp.maximum(ft + m, it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(ft + m - m_new)
        c_new = f_ * c + i_ * zt
        n_new = f_ * n + i_
        h_new = ot * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    zero = jnp.zeros((B, H, hd), jnp.float32)
    (cf, nf, hf, mf), hs = jax.lax.scan(
        step, (zero, zero, zero, zero), pre.transpose(1, 0, 2, 3)
    )
    y = hs.transpose(1, 0, 2, 3).reshape(B, T, d).astype(x.dtype)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)).astype(
        x.dtype
    ) * params["norm"].astype(x.dtype)
    if return_state:
        return y, {"c": cf, "n": nf, "h": hf, "m": mf}
    return y


def slstm_init_state(cfg: XLSTMConfig, batch: int, dtype=jnp.float32) -> dict:
    H = cfg.n_heads
    hd = cfg.d_model // H
    zero = jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": zero, "n": zero, "h": zero, "m": zero}


def slstm_decode_step(params, cfg: XLSTMConfig, x, state):
    B, _, d = x.shape
    H = cfg.n_heads
    hd = d // H
    wz = jnp.stack([params[f"w_{g}"] for g in "zifo"]).astype(x.dtype)
    bz = jnp.stack([params[f"b_{g}"] for g in "zifo"]).astype(jnp.float32)
    rz = jnp.stack([params[f"r_{g}"] for g in "zifo"]).astype(jnp.float32)
    pre = jnp.einsum("bd,gde->bge", x[:, 0], wz).astype(jnp.float32) + bz[None]
    rec = jnp.einsum("bhe,ghef->bghf", state["h"], rz)
    tot = pre.reshape(B, 4, H, hd) + rec
    zt = jnp.tanh(tot[:, 0])
    it = tot[:, 1]
    ft = jax.nn.log_sigmoid(tot[:, 2])
    ot = jax.nn.sigmoid(tot[:, 3])
    m_new = jnp.maximum(ft + state["m"], it)
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(ft + state["m"] - m_new)
    c_new = f_ * state["c"] + i_ * zt
    n_new = f_ * state["n"] + i_
    h_new = ot * c_new / jnp.maximum(n_new, 1.0)
    y = h_new.reshape(B, d).astype(x.dtype)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)).astype(
        x.dtype
    ) * params["norm"].astype(x.dtype)
    return y[:, None], {"c": c_new, "n": n_new, "h": h_new, "m": m_new}
