"""Analytic cost helpers for the roofline (EXPERIMENTS.md §Roofline).

Two uses:

* ``model_flops`` — the brief's MODEL_FLOPS = 6·N·D (train) / 2·N_active·D
  (inference) reference, with MoE active-parameter accounting;
* ``recurrent_adders`` — xLSTM's mLSTM/sLSTM recurrence runs as a
  ``lax.scan`` over time whose body XLA's cost_analysis counts once; the
  cost-faithful dry-run adds (T-1) analytic bodies back (everything else is
  loop-free in cost mode — see launch/dryrun.py --costmode).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.lm import ModelConfig


def n_params(model: ModelConfig) -> int:
    import math

    from repro.models import lm

    abs_p = lm.abstract_params(model)
    # python-int product: jnp.prod overflows int32 on >2B-element tensors
    return sum(math.prod(l.shape) for l in jax.tree.leaves(abs_p))


def n_active_params(model: ModelConfig) -> int:
    """Params touched per token: routed experts scaled by top_k/E."""
    total = n_params(model)
    inactive = 0
    for blocks, mult in (
        (model.unit, model.n_repeats),
        (model.prologue, 1),
        (model.epilogue, 1),
    ):
        for b in blocks:
            if b.kind == "attn_moe" and b.moe is not None:
                m = b.moe
                per_expert = m.d_model * m.d_ff * (3 if m.gated else 2)
                routed = m.n_experts * per_expert
                active = m.top_k * per_expert
                inactive += mult * (routed - active)
    return total - inactive


def model_flops(model: ModelConfig, tokens: int, mode: str) -> float:
    """6·N_active·D for train, 2·N_active·D for prefill/decode forward."""
    na = n_active_params(model)
    mult = 6.0 if mode == "train" else 2.0
    return mult * na * tokens


def analytic_hbm_bytes(
    model: ModelConfig, *, global_batch: int, seq: int, mode: str,
    n_devices: int, tp: int = 16, param_bytes: int = 2,
) -> float:
    """Fusion-aware analytic HBM traffic per device (lower bound).

    XLA's `bytes accessed` counts every HLO operand (pre-fusion) — on TPU,
    fusion keeps attention score tiles, softmax temps etc. in VMEM, so the
    honest roofline brackets memory between this analytic lower bound and
    the HLO upper bound (EXPERIMENTS.md §Roofline).

    Terms: parameter reads (x passes), activation saves/reads at remat
    boundaries, KV-cache traffic, logits.
    """
    from repro.models import lm as _lm
    import jax as _jax
    import jax.numpy as _jnp

    n = n_params(model)
    tokens_dev = global_batch * (seq if mode != "decode" else 1) / n_devices
    d = model.d_model
    L = model.n_layers
    # parameter passes: fwd + bwd + opt (train) / single read (inference)
    passes = 4.0 if mode == "train" else 1.0
    p_bytes = n / tp * param_bytes * passes
    act_bytes = 0.0
    if mode == "train":
        # remat=unit: save + re-read one activation per unit boundary, then
        # recompute: 2 saves+reads per repeat + logits fp32
        act_bytes = model.n_repeats * tokens_dev * d * 2 * 4
        act_bytes += tokens_dev * model.vocab * 4 * 2 / tp
    elif mode == "prefill":
        abs_c = _jax.eval_shape(
            lambda: _lm.init_caches(model, global_batch, seq, _jnp.bfloat16))
        cache = sum(
            int(np_prod(l.shape)) * l.dtype.itemsize
            for l in _jax.tree.leaves(abs_c)
        )
        act_bytes = cache / n_devices + tokens_dev * model.vocab * 4 / tp
    else:  # decode: read the whole cache once
        abs_c = _jax.eval_shape(
            lambda: _lm.init_caches(model, global_batch, seq, _jnp.bfloat16))
        cache = sum(
            int(np_prod(l.shape)) * l.dtype.itemsize
            for l in _jax.tree.leaves(abs_c)
        )
        act_bytes = cache / n_devices
    return p_bytes + act_bytes


def np_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


def recurrent_adders(model: ModelConfig, batch: int, T: int, mode: str) -> dict:
    """FLOPs/bytes of (T-1) extra recurrence-body steps for mLSTM/sLSTM
    blocks (per rep), scaled by repeats. Decode (T=1) needs no adder."""
    if T <= 1:
        return {"flops": 0.0, "bytes": 0.0}
    flops = 0.0
    bytes_ = 0.0
    fwd_mult = 3.0 if mode == "train" else 1.0  # bwd ~ 2x fwd
    for blocks, mult in (
        (model.unit, model.n_repeats),
        (model.prologue, 1),
        (model.epilogue, 1),
    ):
        for b in blocks:
            if (b.kind == "mlstm" and b.xlstm is not None
                    and b.xlstm.mlstm_impl != "chunked"):
                # chunked mLSTM runs loop-free in cost mode (scan_unroll):
                # no adder — its state traffic is counted by XLA directly
                H, D = b.xlstm.n_heads, b.xlstm.head_dim
                # per step: C update (2 fma over H·D²) + decay mult + n/den/num
                body_f = batch * H * (6.0 * D * D + 6.0 * D)
                body_b = batch * H * D * D * 4.0 * 4  # C read+write fp32
                flops += mult * (T - 1) * body_f * fwd_mult
                bytes_ += mult * (T - 1) * body_b * fwd_mult
            if b.kind == "slstm" and b.xlstm is not None:
                H = b.xlstm.n_heads
                hd = model.d_model // H
                body_f = batch * (4 * H * hd * hd * 2 + 12 * H * hd)
                body_b = batch * H * hd * 4.0 * 8
                flops += mult * (T - 1) * body_f * fwd_mult
                bytes_ += mult * (T - 1) * body_b * fwd_mult
    return {"flops": flops, "bytes": bytes_}
