"""Mamba2 (SSD) blocks for zamba2 — chunked-parallel scan, TPU-friendly.

The SSD (state-space duality) formulation splits the sequence into chunks:
within a chunk the recurrence is computed as a masked quadratic form
(MXU-friendly), and a short ``lax.scan`` carries the (H, N, P) state across
chunks. Decode keeps an O(1) recurrent state — this is why zamba2/xlstm are
the archs that run the ``long_500k`` cell (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import ParamCtx


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def mamba2_init(ctx: ParamCtx, cfg: Mamba2Config) -> dict:
    d, di, H = cfg.d_model, cfg.d_inner, cfg.n_heads
    proj_out = 2 * di + 2 * cfg.n_groups * cfg.d_state + H
    return {
        "in_proj": ctx.make((d, proj_out), ("embed", "ffn")),
        "conv_w": ctx.make((cfg.conv_width, cfg.conv_dim), (None, "ffn"), scale=0.5),
        "conv_b": ctx.make((cfg.conv_dim,), ("ffn",), init="zeros"),
        "A_log": ctx.make((H,), ("heads",), init="ones"),
        "D": ctx.make((H,), ("heads",), init="ones"),
        "dt_bias": ctx.make((H,), ("heads",), init="zeros"),
        "norm": ctx.make((di,), ("ffn",), init="ones"),
        "out_proj": ctx.make((di, d), ("ffn", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, T, C) with width-W kernel (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(W)
    )
    return out + b.astype(x.dtype)


def _ssd_chunked(xh, Bm, Cm, dt, A, chunk, return_final=False):
    """SSD scan. xh: (B,T,H,P); Bm/Cm: (B,T,G,N); dt: (B,T,H); A: (H,) < 0.

    Returns (B, T, H, P). Heads are grouped: H/G heads share each B/C group.
    """
    Bsz, T, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    nc = T // chunk
    Q = chunk

    def r(t):  # (B, T, ...) -> (B, nc, Q, ...)
        return t.reshape((Bsz, nc, Q) + t.shape[2:])

    xh_c, B_c, C_c, dt_c = r(xh), r(Bm), r(Cm), r(dt)
    a = dt_c * A.astype(dt.dtype)                        # (B,nc,Q,H) log-decay
    cs = jnp.cumsum(a, axis=2)                           # cumulative in-chunk

    # Intra-chunk (quadratic, masked): Y[i] += sum_{j<=i} C_i·B_j decay(j->i) dt_j x_j
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]    # (B,nc,Qi,Qj,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    CB = jnp.einsum("bcign,bcjgn->bcijg", C_c, B_c)      # (B,nc,Qi,Qj,G)
    CB = jnp.repeat(CB, rep, axis=-1)                    # -> heads
    Ydiag = jnp.einsum(
        "bcijh,bcijh,bcjh,bcjhp->bcihp",
        CB.astype(jnp.float32), decay.astype(jnp.float32),
        dt_c.astype(jnp.float32), xh_c.astype(jnp.float32),
    )

    # Chunk states: S_c = sum_j decay(j->end) dt_j B_j x_j^T  (B,nc,H,N,P)
    dec_end = jnp.exp(cs[:, :, -1:, :] - cs)             # (B,nc,Q,H)
    Bh = jnp.repeat(B_c, rep, axis=-2)                   # (B,nc,Q,H,N)
    S = jnp.einsum(
        "bcqh,bcqh,bcqhn,bcqhp->bchnp",
        dec_end.astype(jnp.float32), dt_c.astype(jnp.float32),
        Bh.astype(jnp.float32), xh_c.astype(jnp.float32),
    )
    chunk_decay = jnp.exp(cs[:, :, -1, :])               # (B,nc,H)

    def scan_fn(carry, inp):
        S_prev = carry
        S_c, dec = inp                                   # (B,H,N,P), (B,H)
        S_new = S_c + dec[..., None, None] * S_prev
        return S_new, S_prev

    S0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    S_final, S_prevs = jax.lax.scan(
        scan_fn, S0, (S.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2))
    )
    S_prevs = S_prevs.transpose(1, 0, 2, 3, 4)           # (B,nc,H,N,P)

    # Inter-chunk: Y[i] += C_i · exp(cs_i) · S_prev
    Ch = jnp.repeat(C_c, rep, axis=-2)                   # (B,nc,Q,H,N)
    Yoff = jnp.einsum(
        "bcqhn,bcqh,bchnp->bcqhp",
        Ch.astype(jnp.float32), jnp.exp(cs).astype(jnp.float32), S_prevs,
    )
    y = (Ydiag + Yoff).reshape(Bsz, T, H, P)
    if return_final:
        return y, S_final
    return y


def mamba2_forward(
    params: dict, cfg: Mamba2Config, x: jax.Array, return_state: bool = False
):
    """x: (B, T, d_model) -> (B, T, d_model). T must be chunk-padded.
    With ``return_state``, also returns the decode state (prefill)."""
    B, T, _ = x.shape
    di, H, P, G, N = cfg.d_inner, cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z, xbc_raw, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc_raw, params["conv_w"], params["conv_b"]))
    xs, Bm, Cm = jnp.split(xbc, [di, di + G * N], axis=-1)
    xh = xs.reshape(B, T, H, P)
    Bm = Bm.reshape(B, T, G, N)
    Cm = Cm.reshape(B, T, G, N)
    dt = jax.nn.softplus(dt + params["dt_bias"].astype(x.dtype))     # (B,T,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    chunk = min(cfg.chunk, T)
    if return_state:
        y, S_final = _ssd_chunked(xh, Bm, Cm, dt, A, chunk, return_final=True)
        W = cfg.conv_width
        conv_state = jnp.concatenate(
            [jnp.zeros((B, max(0, W - 1 - T), cfg.conv_dim), x.dtype),
             xbc_raw[:, max(0, T - (W - 1)):]], axis=1)
    else:
        y = _ssd_chunked(xh, Bm, Cm, dt, A, chunk)
    y = y + (params["D"].astype(jnp.float32))[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, T, di).astype(x.dtype)
    # gated RMSNorm (Mamba2's norm(z-gate) variant)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)).astype(
        x.dtype
    ) * params["norm"].astype(x.dtype)
    out = y @ params["out_proj"].astype(x.dtype)
    if return_state:
        return out, {"ssm": S_final, "conv": conv_state}
    return out


# ------------------------------- decode ------------------------------------


def mamba2_init_state(cfg: Mamba2Config, batch: int, dtype=jnp.float32) -> dict:
    return {
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.conv_dim), dtype),
    }


def mamba2_decode_step(
    params: dict, cfg: Mamba2Config, x: jax.Array, state: dict
) -> tuple[jax.Array, dict]:
    """x: (B, 1, d) one token; O(1) state update (the long_500k path)."""
    B = x.shape[0]
    di, H, P, G, N = cfg.d_inner, cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    zxbcdt = x[:, 0] @ params["in_proj"].astype(x.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    # conv buffer update
    buf = jnp.concatenate([state["conv"], xbc[:, None]], axis=1)      # (B, W, C)
    w = params["conv_w"].astype(x.dtype)
    xbc = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", buf, w) + params["conv_b"].astype(x.dtype)
    )
    new_conv = buf[:, 1:]
    xs, Bm, Cm = jnp.split(xbc, [di, di + G * N], axis=-1)
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    Bm = jnp.repeat(Bm.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    Cm = jnp.repeat(Cm.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dt + params["dt_bias"].astype(x.dtype)).astype(jnp.float32)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)                                           # (B,H)
    S = state["ssm"] * decay[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhnp", dt, Bm, xh
    )
    y = jnp.einsum("bhn,bhnp->bhp", Cm, S)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, di).astype(x.dtype) * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)).astype(
        x.dtype
    ) * params["norm"].astype(x.dtype)
    y = y @ params["out_proj"].astype(x.dtype)
    return y[:, None], {"ssm": S, "conv": new_conv}
