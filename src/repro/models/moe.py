"""Mixture-of-Experts FFN (OLMoE, DeepSeek-V2-Lite) with expert parallelism.

Token-choice top-k routing with a capacity factor, dispatch/combine as
one-hot einsums (MXU-native, the standard TPU MoE formulation — a gather-based
dispatch would serialise on sparse cores). Experts shard over the ``model``
mesh axis (EP); with 64 experts on a 16-way axis that is 4 experts/device.

Aux losses: load-balance (Switch-style) + router z-loss, returned for the
training objective.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import ParamCtx


def _maybe_constrain(x, *axes):
    """with_sharding_constraint if the ambient mesh has the named axes
    (no-op on host/test meshes). Critical for MoE under DP: without a
    (experts->model, capacity->data) constraint on the dispatched slots,
    GSPMD replicates the whole expert computation across the data axis —
    measured 16x FLOP waste (EXPERIMENTS.md SecPerf iteration 7)."""
    from jax._src import mesh as mesh_lib

    m = mesh_lib.thread_resources.env.physical_mesh
    if m.empty or not all(a is None or a in m.shape for a in axes):
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*axes))


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                   # per-expert hidden
    n_experts: int
    top_k: int
    n_shared: int = 0           # DeepSeek shared experts (always-on)
    capacity_factor: float = 1.25
    gated: bool = True          # SwiGLU experts
    # "scatter": O(S·d) scatter/gather dispatch (production default).
    # "einsum": classic one-hot dispatch — O(S²·d/E) because cap ∝ S; kept
    # as the §Perf baseline it was replaced by (see EXPERIMENTS.md).
    dispatch: str = "scatter"


def moe_init(ctx: ParamCtx, cfg: MoEConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": ctx.make((d, e), ("embed", "experts"), scale=0.02),
        "wi": ctx.make((e, d, f), ("experts", "embed", "ffn")),
        "wo": ctx.make((e, f, d), ("experts", "ffn", "embed")),
    }
    if cfg.gated:
        p["wg"] = ctx.make((e, d, f), ("experts", "embed", "ffn"))
    if cfg.n_shared:
        fs = f * cfg.n_shared
        p["shared_wi"] = ctx.make((d, fs), ("embed", "ffn"))
        p["shared_wg"] = ctx.make((d, fs), ("embed", "ffn"))
        p["shared_wo"] = ctx.make((fs, d), ("ffn", "embed"))
    return p


def _expert_ffn(p: dict, xe: jax.Array, gated: bool) -> jax.Array:
    """xe: (E, C, d) -> (E, C, d) through each expert's (Sw)iGLU FFN."""
    h = jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(xe.dtype))
    if gated:
        g = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(xe.dtype))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(xe.dtype))


def moe_forward(
    params: dict, cfg: MoEConfig, x: jax.Array
) -> tuple[jax.Array, dict]:
    """x: (B, T, d) -> (y, aux) with einsum dispatch/combine."""
    B, T, d = x.shape
    S = B * T
    xf = x.reshape(S, d)
    logits = (xf.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (S, E)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)             # (S, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    E = cfg.n_experts
    cap = int(max(cfg.top_k, cfg.capacity_factor * S * cfg.top_k / E))
    # position of each (token, choice) in its expert's queue
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.int32)         # (S, k, E)
    flat = onehot.reshape(S * cfg.top_k, E)
    pos_in_e = (jnp.cumsum(flat, axis=0) - flat).reshape(S, cfg.top_k, E)
    pos = (pos_in_e * onehot).sum(-1)                          # (S, k)
    keep = pos < cap

    if cfg.dispatch == "scatter":
        # O(S·k·d) data movement: scatter tokens into (E, cap, d) slots,
        # gather them back weighted — no S x (E·cap) contraction.
        slot = jnp.where(keep, top_e * cap + pos, E * cap)     # drop -> OOB
        xe = jnp.zeros((E * cap + 1, d), xf.dtype).at[
            slot.reshape(-1)
        ].add(jnp.repeat(xf, cfg.top_k, axis=0))
        xe = xe[:-1].reshape(E, cap, d)
        # NOTE (EXPERIMENTS.md SecPerf iteration 7, refuted): forcing an
        # (experts->model, cap->data) constraint here doubles collective
        # traffic without de-replicating the expert einsums — GSPMD's
        # scatter partitioning is the blocker. The production fix is a
        # shard_map-local dispatch (per-shard top-k + all-to-all), logged
        # as the next step.
        ye = _expert_ffn(params, xe, cfg.gated)                # (E, cap, d)
        gathered = ye.reshape(E * cap, d)[
            jnp.clip(slot, 0, E * cap - 1).reshape(-1)
        ].reshape(S, cfg.top_k, d)
        w = (top_p.astype(xf.dtype) * keep.astype(xf.dtype))[..., None]
        y = (gathered * w).sum(axis=1)
    else:  # einsum baseline (paper-era TPU MoE; O(S²) — see EXPERIMENTS.md)
        disp = (
            jax.nn.one_hot(top_e, E, dtype=xf.dtype)[..., None]
            * jax.nn.one_hot(pos, cap, dtype=xf.dtype)[..., None, :]
            * keep[..., None, None].astype(xf.dtype)
        )                                                      # (S, k, E, cap)
        disp_tok = disp.sum(1)                                 # (S, E, cap)
        xe = jnp.einsum("sec,sd->ecd", disp_tok, xf)           # (E, cap, d)
        ye = _expert_ffn(params, xe, cfg.gated)                # (E, cap, d)
        comb = (disp * top_p[..., None, None].astype(xf.dtype)).sum(1)
        y = jnp.einsum("sec,ecd->sd", comb, ye)

    if cfg.n_shared:
        h = xf @ params["shared_wi"].astype(xf.dtype)
        g = xf @ params["shared_wg"].astype(xf.dtype)
        y = y + (jax.nn.silu(g) * h) @ params["shared_wo"].astype(xf.dtype)

    # aux losses (fp32)
    me = probs.mean(0)                                          # (E,)
    ce = jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32).mean(0)
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    drop_frac = 1.0 - keep.astype(jnp.float32).mean()
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss, "moe_drop_frac": drop_frac}
    return y.reshape(B, T, d), aux
