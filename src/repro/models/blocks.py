"""Composable decoder blocks.

A model is a *pattern* of blocks (DESIGN.md §3): a repeating unit scanned
``n_repeats`` times (stacked params, O(1) HLO in depth) plus optional
prologue/epilogue blocks and *shared* blocks (zamba2's shared attention:
one parameter set invoked at several depths).

Block kinds: ``attn_mlp``, ``attn_moe``, ``mamba2``, ``mlstm``, ``slstm``,
``attn_kan`` (the paper's technique as an FFN replacement), and the windowed
variants via ``AttnConfig.window``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import kan_layer as KL
from repro.core.bspline import SplineGrid
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import xlstm as X
from repro.models.layers import ParamCtx


@dataclasses.dataclass(frozen=True)
class BlockCfg:
    kind: str
    attn: A.AttnConfig | None = None
    d_ff: int = 0                       # dense (SwiGLU) FFN hidden size
    moe: M.MoEConfig | None = None
    mamba: S.Mamba2Config | None = None
    xlstm: X.XLSTMConfig | None = None
    kan_grid: SplineGrid | None = None  # attn_kan
    kan_ff: int = 0
    shared_id: int | None = None        # reference into the model's shared set


def _mlp_init(ctx: ParamCtx, d: int, ff: int) -> dict:
    return {
        "wi": ctx.make((d, ff), ("embed", "ffn")),
        "wg": ctx.make((d, ff), ("embed", "ffn")),
        "wo": ctx.make((ff, d), ("ffn", "embed")),
    }


def _mlp(params: dict, x: jax.Array) -> jax.Array:
    h = x @ params["wi"].astype(x.dtype)
    g = x @ params["wg"].astype(x.dtype)
    return (jax.nn.silu(g) * h) @ params["wo"].astype(x.dtype)


def _kan_ffn_init(ctx: ParamCtx, d: int, ff: int, grid: SplineGrid) -> dict:
    """KAN FFN: two spline layers d -> ff -> d (the paper's technique as a
    first-class FFN replacement; coefficients carry the basis axis)."""
    M_ = grid.n_basis
    return {
        "c1": ctx.make((d, M_, ff), ("embed", None, "ffn"), scale=0.02),
        "b1": ctx.make((d, ff), ("embed", "ffn"), scale=0.02),
        "c2": ctx.make((ff, M_, d), ("ffn", None, "embed"), scale=0.02),
        "b2": ctx.make((ff, d), ("ffn", "embed"), scale=0.02),
    }


def _kan_ffn(
    params: dict, x: jax.Array, grid: SplineGrid, method: str = "dense"
) -> jax.Array:
    """Two spline layers d -> ff -> d.

    ``method="dense"`` is the differentiable training path; inference
    callers (prefill/decode) pass ``method="auto"``, which resolves per
    backend AND batch regime (``KL.resolve_inference_method``): on TPU the
    sparse N:M kernel at decode row counts, the fused kernel for
    prefill/large batch; ``compact`` elsewhere.
    """
    lead = x.shape[:-1]
    xf = jnp.tanh(x.reshape(-1, x.shape[-1]))   # squash into the spline domain
    h = KL.kan_layer_apply(
        {"coeff": params["c1"], "base_w": params["b1"]}, xf, grid, method
    )
    h = jnp.tanh(h)
    y = KL.kan_layer_apply(
        {"coeff": params["c2"], "base_w": params["b2"]}, h, grid, method
    )
    return y.reshape(lead + (y.shape[-1],)).astype(x.dtype)


def block_init(ctx: ParamCtx, d_model: int, blk: BlockCfg) -> dict:
    p: dict = {"ln1": L.rmsnorm_init(ctx, d_model)}
    if blk.kind in ("attn_mlp", "attn_moe", "attn_kan"):
        p["attn"] = A.attn_init(ctx, blk.attn)
        p["ln2"] = L.rmsnorm_init(ctx, d_model)
        if blk.kind == "attn_mlp":
            p["mlp"] = _mlp_init(ctx, d_model, blk.d_ff)
        elif blk.kind == "attn_moe":
            p["moe"] = M.moe_init(ctx, blk.moe)
        else:
            p["kan"] = _kan_ffn_init(ctx, d_model, blk.kan_ff, blk.kan_grid)
    elif blk.kind == "mamba2":
        p["mamba"] = S.mamba2_init(ctx, blk.mamba)
    elif blk.kind == "mlstm":
        p["mlstm"] = X.mlstm_init(ctx, blk.xlstm)
    elif blk.kind == "slstm":
        p["slstm"] = X.slstm_init(ctx, blk.xlstm)
    else:
        raise ValueError(blk.kind)
    return p


def block_apply(
    params: dict,
    blk: BlockCfg,
    x: jax.Array,
    *,
    positions: jax.Array | None = None,
    chunk: int = 1024,
) -> tuple[jax.Array, dict]:
    """Pre-norm residual application; returns (x, aux_losses)."""
    aux: dict = {}
    h = L.rmsnorm(params["ln1"], x)
    if blk.kind in ("attn_mlp", "attn_moe", "attn_kan"):
        x = x + A.attn_forward(params["attn"], blk.attn, h, positions=positions, chunk=chunk)
        h2 = L.rmsnorm(params["ln2"], x)
        if blk.kind == "attn_mlp":
            x = x + _mlp(params["mlp"], h2)
        elif blk.kind == "attn_moe":
            y, aux = M.moe_forward(params["moe"], blk.moe, h2)
            x = x + y
        else:
            x = x + _kan_ffn(params["kan"], h2, blk.kan_grid)
    elif blk.kind == "mamba2":
        x = x + S.mamba2_forward(params["mamba"], blk.mamba, h)
    elif blk.kind == "mlstm":
        x = x + X.mlstm_forward(params["mlstm"], blk.xlstm, h)
    elif blk.kind == "slstm":
        x = x + X.slstm_forward(params["slstm"], blk.xlstm, h)
    return x, aux


# ----------------------------- decode support -------------------------------


def block_init_cache(
    blk: BlockCfg, batch: int, max_seq: int, dtype
) -> dict:
    """Per-block decode state (KV cache / SSM state / LSTM state).

    Windowed attention allocates a ``window``-slot ring buffer; kv_quant
    stores int8 values + per-(token, kv-head) fp32 scales."""
    if blk.kind in ("attn_mlp", "attn_moe", "attn_kan"):
        c = blk.attn
        if c.kv_lora_rank is not None:
            return {
                "ckv": jnp.zeros(
                    (batch, max_seq, c.kv_lora_rank + c.qk_rope_dim), dtype
                )
            }
        S_ = c.cache_len(max_seq)
        kv_dtype = jnp.int8 if c.kv_quant else dtype
        cache = {
            "k": jnp.zeros((batch, S_, c.n_kv_heads, c.head_dim), kv_dtype),
            "v": jnp.zeros((batch, S_, c.n_kv_heads, c.head_dim), kv_dtype),
        }
        if c.kv_quant:
            cache["k_scale"] = jnp.zeros((batch, S_, c.n_kv_heads), jnp.float32)
            cache["v_scale"] = jnp.zeros((batch, S_, c.n_kv_heads), jnp.float32)
        return cache
    if blk.kind == "mamba2":
        return S.mamba2_init_state(blk.mamba, batch, dtype)
    if blk.kind == "mlstm":
        return X.mlstm_init_state(blk.xlstm, batch, dtype)
    if blk.kind == "slstm":
        return X.slstm_init_state(blk.xlstm, batch, dtype)
    raise ValueError(blk.kind)


def block_supports_paging(blk: BlockCfg) -> bool:
    """Paged KV (DESIGN.md §3b) covers full-attention GQA layers: windowed
    ring buffers already bound their cache to ``window`` slots, MLA latents
    and SSM/LSTM states are per-sequence (not per-token) — none of them
    strand per-token HBM the way a dense ``max_seq`` KV row does."""
    return (
        blk.kind in ("attn_mlp", "attn_moe", "attn_kan")
        and blk.attn.kv_lora_rank is None
        and blk.attn.window is None
    )


def block_init_paged_cache(
    blk: BlockCfg, n_blocks: int, block_size: int, dtype
) -> dict:
    """Pool-shaped decode cache: ``(n_blocks, block_size, ...)`` leaves in
    place of :func:`block_init_cache`'s ``(batch, max_seq, ...)`` rows.
    Physical block 0 is the engine's reserved sentinel (``serve/kv_pool.py``).
    """
    if not block_supports_paging(blk):
        raise NotImplementedError(
            f"paged KV cache: unsupported block kind {blk.kind!r} "
            "(full-attention GQA layers only)"
        )
    c = blk.attn
    kv_dtype = jnp.int8 if c.kv_quant else dtype
    cache = {
        "k": jnp.zeros((n_blocks, block_size, c.n_kv_heads, c.head_dim), kv_dtype),
        "v": jnp.zeros((n_blocks, block_size, c.n_kv_heads, c.head_dim), kv_dtype),
    }
    if c.kv_quant:
        cache["k_scale"] = jnp.zeros((n_blocks, block_size, c.n_kv_heads), jnp.float32)
        cache["v_scale"] = jnp.zeros((n_blocks, block_size, c.n_kv_heads), jnp.float32)
    return cache


def block_prefill(
    params: dict,
    blk: BlockCfg,
    x: jax.Array,                  # (B, T, d)
    *,
    positions: jax.Array | None = None,
    max_seq: int,
    chunk: int = 1024,
    shard=None,                    # optional ShardingCtx (mesh serving)
) -> tuple[jax.Array, dict]:
    """Forward + decode-cache production (KV padded to ``max_seq``).

    With ``shard`` the produced cache leaves are constraint-pinned to the
    shardings their logical axes derive (``block_cache_axes``), so a jitted
    sharded prefill hands decode a distributed cache, not a gathered one."""
    B, T, _ = x.shape
    h = L.rmsnorm(params["ln1"], x)
    if blk.kind in ("attn_mlp", "attn_moe", "attn_kan"):
        c = blk.attn
        y, kv = A.attn_forward(
            params["attn"], c, h, positions=positions, chunk=chunk,
            return_cache=True,
        )
        if "k" in kv:  # GQA path: ring placement + optional int8
            S_ = c.cache_len(max_seq)
            if c.window and S_ < T:
                # ring semantics: slot s holds the latest position p < T with
                # p % S_ == s (matches decode's slot = pos % window)
                s_idx = jnp.arange(S_)
                p_s = (T - 1) - ((T - 1 - s_idx) % S_)
                kv = jax.tree.map(lambda a: a[:, p_s], kv)
            elif S_ > T:
                kv = jax.tree.map(
                    lambda a: jnp.pad(
                        a, ((0, 0), (0, S_ - T)) + ((0, 0),) * (a.ndim - 2)
                    ),
                    kv,
                )
            if c.kv_quant:
                kq, ks = A._kv_quantize(kv["k"])
                vq, vs = A._kv_quantize(kv["v"])
                cache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
            else:
                cache = kv
        else:  # MLA latent cache
            pad = max_seq - T
            cache = jax.tree.map(
                lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0))), kv
            )
        x = x + y
        h2 = L.rmsnorm(params["ln2"], x)
        if blk.kind == "attn_mlp":
            x = x + _mlp(params["mlp"], h2)
        elif blk.kind == "attn_moe":
            y2, _ = M.moe_forward(params["moe"], blk.moe, h2)
            x = x + y2
        else:
            # inference path, batch-regime aware: fused Pallas kernel on TPU
            # at prefill row counts, sparse at decode, compact elsewhere
            x = x + _kan_ffn(params["kan"], h2, blk.kan_grid, method="auto")
        if shard is not None:
            cache = shard.constrain_tree(cache, block_cache_axes(blk))
        return x, cache
    if blk.kind == "mamba2":
        y, st = S.mamba2_forward(params["mamba"], blk.mamba, h, return_state=True)
    elif blk.kind == "mlstm":
        y, st = X.mlstm_forward(params["mlstm"], blk.xlstm, h, return_state=True)
    elif blk.kind == "slstm":
        y, st = X.slstm_forward(params["slstm"], blk.xlstm, h, return_state=True)
    else:
        raise ValueError(blk.kind)
    if shard is not None:
        st = shard.constrain_tree(st, block_cache_axes(blk))
    return x + y, st


def block_prefill_paged(
    params: dict,
    blk: BlockCfg,
    x: jax.Array,                  # (B, Ts, d) — uncached suffix tokens only
    *,
    positions: jax.Array,          # (B, Ts) absolute positions
    cache: dict,                   # pool leaves (n_blocks, bs, ...)
    table: jax.Array,              # (B, n_logical)
    lengths: jax.Array,            # (B,) true total prompt lengths
    start: jax.Array,              # scalar: first uncached position
    chunk: int = 1024,
    view_blocks: int | None = None,
    shard=None,                    # optional ShardingCtx (mesh serving)
) -> tuple[jax.Array, dict]:
    """Suffix prefill writing K/V straight into pool blocks — the paged
    counterpart of :func:`block_prefill` (which pads a private cache row to
    ``max_seq`` for splicing).  Prefix-cache hits enter with ``start > 0``
    and skip the cached positions entirely."""
    if not block_supports_paging(blk):
        raise NotImplementedError(f"paged prefill: unsupported kind {blk.kind!r}")
    h = L.rmsnorm(params["ln1"], x)
    y, cache = A.attn_prefill_paged(
        params["attn"], blk.attn, h, positions, cache, table, lengths, start,
        chunk=chunk, view_blocks=view_blocks, shard=shard,
    )
    x = x + y
    h2 = L.rmsnorm(params["ln2"], x)
    if blk.kind == "attn_mlp":
        x = x + _mlp(params["mlp"], h2)
    elif blk.kind == "attn_moe":
        y2, _ = M.moe_forward(params["moe"], blk.moe, h2)
        x = x + y2
    else:
        # same batch-regime-aware inference path as block_prefill — row
        # counts differ (suffix only), but every KAN method is row-wise
        x = x + _kan_ffn(params["kan"], h2, blk.kan_grid, method="auto")
    return x, cache


def block_paged_cache_axes(blk: BlockCfg) -> dict:
    """Logical axes of the pool-shaped cache (mirrors
    :func:`block_init_paged_cache`): the batch axis is gone — sharding can
    split the pool along ``kv_blocks`` (the paged analogue of
    ``seq_cache``) or the head axes."""
    from repro.models.layers import Axes

    assert block_supports_paging(blk)
    axes = {
        "k": Axes(("kv_blocks", None, "kv_heads", "head_dim")),
        "v": Axes(("kv_blocks", None, "kv_heads", "head_dim")),
    }
    if blk.attn.kv_quant:
        axes["k_scale"] = Axes(("kv_blocks", None, "kv_heads"))
        axes["v_scale"] = Axes(("kv_blocks", None, "kv_heads"))
    return axes


def block_cache_axes(blk: BlockCfg) -> dict:
    """Logical axes of the decode state (mirrors block_init_cache).

    ``seq_cache`` lets long-context decode shard the KV cache's sequence dim
    on the data axis when the batch cannot occupy it (long_500k, B=1).
    """
    from repro.models.layers import Axes

    if blk.kind in ("attn_mlp", "attn_moe", "attn_kan"):
        if blk.attn.kv_lora_rank is not None:
            return {"ckv": Axes(("batch", "seq_cache", "kv_lora"))}
        axes = {
            "k": Axes(("batch", "seq_cache", "kv_heads", "head_dim")),
            "v": Axes(("batch", "seq_cache", "kv_heads", "head_dim")),
        }
        if blk.attn.kv_quant:
            axes["k_scale"] = Axes(("batch", "seq_cache", "kv_heads"))
            axes["v_scale"] = Axes(("batch", "seq_cache", "kv_heads"))
        return axes
    if blk.kind == "mamba2":
        return {
            "ssm": Axes(("batch", "heads", "state", "head_dim")),
            "conv": Axes(("batch", None, "ffn")),
        }
    if blk.kind == "mlstm":
        return {
            "C": Axes(("batch", "heads", "head_dim", "head_dim")),
            "n": Axes(("batch", "heads", "head_dim")),
            "m": Axes(("batch", "heads")),
            "conv": Axes(("batch", None, "ffn")),
        }
    if blk.kind == "slstm":
        # sLSTM gates are per-unit: all four state tensors are (B, H, hd)
        ax = Axes(("batch", "heads", "head_dim"))
        return {"c": ax, "n": ax, "h": ax, "m": ax}
    raise ValueError(blk.kind)


def block_decode_step(
    params: dict,
    blk: BlockCfg,
    x: jax.Array,               # (B, 1, d)
    cache: dict,
    pos: jax.Array,             # (B,)
    table: jax.Array | None = None,   # (B, n_logical): paged block table
    shard=None,                 # optional ShardingCtx (mesh serving)
) -> tuple[jax.Array, dict]:
    h = L.rmsnorm(params["ln1"], x)
    if table is not None and not block_supports_paging(blk):
        raise NotImplementedError(f"paged decode: unsupported kind {blk.kind!r}")
    if blk.kind in ("attn_mlp", "attn_moe", "attn_kan"):
        c = blk.attn
        if table is not None:
            y, cache = A.attn_decode_step_paged(
                params["attn"], c, h, cache, table, pos, shard=shard
            )
        elif c.kv_lora_rank is not None:
            # shard threads into the step itself (the latent is pinned at
            # the write, like the GQA paths) — no caller-side special case
            y, ckv = A.mla_decode_step(
                params["attn"], c, h, cache["ckv"], pos, shard=shard
            )
            cache = {"ckv": ckv}
        else:
            y, cache = A.attn_decode_step(
                params["attn"], c, h, cache, pos, shard=shard
            )
        x = x + y
        h2 = L.rmsnorm(params["ln2"], x)
        if blk.kind == "attn_mlp":
            x = x + _mlp(params["mlp"], h2)
        elif blk.kind == "attn_moe":
            y2, _ = M.moe_forward(params["moe"], blk.moe, h2)
            x = x + y2
        else:
            # inference path, batch-regime aware: decode sees B·1 rows, so
            # "auto" resolves to the sparse N:M kernel on TPU
            x = x + _kan_ffn(params["kan"], h2, blk.kan_grid, method="auto")
        return x, cache
    if blk.kind == "mamba2":
        y, cache = S.mamba2_decode_step(params["mamba"], blk.mamba, h, cache)
    elif blk.kind == "mlstm":
        y, cache = X.mlstm_decode_step(params["mlstm"], blk.xlstm, h, cache)
    elif blk.kind == "slstm":
        y, cache = X.slstm_decode_step(params["slstm"], blk.xlstm, h, cache)
    else:
        raise ValueError(blk.kind)
    if shard is not None:
        cache = shard.constrain_tree(cache, block_cache_axes(blk))
    return x + y, cache


def block_verify_window(
    params: dict,
    blk: BlockCfg,
    x: jax.Array,               # (B, W, d) — last accepted token + k drafts
    cache: dict,
    pos: jax.Array,             # (B,) window start positions
    table: jax.Array | None = None,   # (B, n_logical): paged block table
    shard=None,                 # optional ShardingCtx (mesh serving)
) -> tuple[jax.Array, dict]:
    """Speculative verify: :func:`block_decode_step` for a W-token window in
    one batch-shaped pass.  Restricted to the paged-capable block set (full
    attention GQA) — ring buffers and recurrent states are inherently
    sequential in the window dimension.  The FFN sees ``B·W`` rows, so
    ``method="auto"`` resolves to the *fused* kernel regime on TPU — the
    shape conversion speculative decoding exists to buy (DESIGN.md §9)."""
    if not block_supports_paging(blk):
        raise NotImplementedError(
            f"speculative verify: unsupported kind {blk.kind!r} "
            "(full-attention GQA layers only)"
        )
    h = L.rmsnorm(params["ln1"], x)
    c = blk.attn
    if table is not None:
        y, cache = A.attn_verify_window_paged(
            params["attn"], c, h, cache, table, pos, shard=shard
        )
    else:
        y, cache = A.attn_verify_window(
            params["attn"], c, h, cache, pos, shard=shard
        )
    x = x + y
    h2 = L.rmsnorm(params["ln2"], x)
    if blk.kind == "attn_mlp":
        x = x + _mlp(params["mlp"], h2)
    elif blk.kind == "attn_moe":
        y2, _ = M.moe_forward(params["moe"], blk.moe, h2)
        x = x + y2
    else:
        x = x + _kan_ffn(params["kan"], h2, blk.kan_grid, method="auto")
    return x, cache
