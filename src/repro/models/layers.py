"""Parameter machinery + basic NN layers (pure JAX, no flax).

Every parameter is created through a :class:`ParamCtx`, which runs the same
model-definition code in three modes:

* ``init``     — real arrays (smoke tests, examples, training);
* ``abstract`` — ``jax.ShapeDtypeStruct`` stand-ins (the multi-pod dry-run:
  no allocation, shardable);
* ``axes``     — :class:`Axes` leaves naming the *logical* axes of each
  parameter (consumed by ``repro.dist.sharding`` to build PartitionSpecs).

This single-source-of-truth pattern guarantees the three trees are
structurally identical, which the dry-run and checkpointing rely on.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Axes:
    """Logical axis names of one parameter; a pytree *leaf* (deliberately NOT
    registered with jax.tree_util, so tree.map visits it as a leaf)."""

    names: tuple[str | None, ...]


class ParamCtx:
    """Single-source-of-truth parameter factory (see module docstring)."""

    def __init__(self, mode: str, key: jax.Array | None = None, dtype=jnp.float32):
        assert mode in ("init", "abstract", "axes")
        if mode == "init" and key is None:
            raise ValueError("init mode needs a PRNG key")
        self.mode = mode
        self._key = key
        self.dtype = dtype

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def make(
        self,
        shape: tuple[int, ...],
        axes: tuple[str | None, ...],
        init: str = "normal",
        scale: float | None = None,
        dtype=None,
    ):
        assert len(shape) == len(axes), (shape, axes)
        dtype = dtype or self.dtype
        if self.mode == "axes":
            return Axes(tuple(axes))
        if self.mode == "abstract":
            return jax.ShapeDtypeStruct(shape, dtype)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if scale is None:
            # fan-in scaling over all but the last axis
            fan_in = max(1, math.prod(shape[:-1]))
            scale = 1.0 / math.sqrt(fan_in)
        return (scale * jax.random.normal(self._next_key(), shape)).astype(dtype)

    def stacked(self, n: int, fn: Callable[["ParamCtx"], dict]) -> dict:
        """Stack ``n`` copies of a sub-tree along a new leading 'layers' axis
        (the scan-over-layers representation)."""
        if self.mode == "axes":
            t = fn(self)
            return jax.tree.map(lambda a: Axes(("layers",) + a.names), t)
        if self.mode == "abstract":
            t = fn(self)
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), t
            )
        trees = [fn(self) for _ in range(n)]
        return jax.tree.map(lambda *ls: jnp.stack(ls), *trees)


# ---------------------------------------------------------------------------
# Basic layers.
# ---------------------------------------------------------------------------


def rmsnorm_init(ctx: ParamCtx, dim: int) -> dict:
    return {"scale": ctx.make((dim,), ("embed",), init="ones")}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return out.astype(dt)


def embed_init(ctx: ParamCtx, vocab: int, dim: int) -> dict:
    return {"table": ctx.make((vocab, dim), ("vocab", "embed"), scale=1.0)}


def embed_lookup(params: dict, ids: jax.Array, compute_dtype) -> jax.Array:
    return params["table"].astype(compute_dtype)[ids]


def unembed_logits(params: dict, h: jax.Array) -> jax.Array:
    """Tied unembedding: logits in fp32 for a stable softmax/xent."""
    return jnp.einsum(
        "...d,vd->...v", h.astype(jnp.float32), params["table"].astype(jnp.float32)
    )


def rotary_embedding(
    positions: jax.Array, head_dim: int, theta: float = 10000.0, dtype=jnp.float32
) -> tuple[jax.Array, jax.Array]:
    """(positions...) -> cos/sin of shape positions.shape + (head_dim//2,)."""
    half = head_dim // 2
    freqs = (theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., T, H, D); cos/sin: (..., T, D/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def linear_init(
    ctx: ParamCtx,
    in_dim: int,
    out_dim: int,
    axes: tuple[str | None, str | None],
    bias: bool = False,
    scale: float | None = None,
) -> dict:
    p = {"w": ctx.make((in_dim, out_dim), axes, scale=scale)}
    if bias:
        p["b"] = ctx.make((out_dim,), (axes[1],), init="zeros")
    return p


def linear(params: dict, x: jax.Array) -> jax.Array:
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y
