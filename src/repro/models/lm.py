"""Decoder LM: pattern-of-blocks with scan-over-repeats.

The model is ``prologue + unit * n_repeats + epilogue`` (DESIGN.md §3);
the unit's parameters are stacked along a leading 'layers' axis and driven
by ``lax.scan``, so the HLO is O(unit length), not O(depth) — this is what
makes 64-layer × 512-device dry-runs compile fast.

Shared blocks (zamba2): parameters created once under ``params["shared"]``,
closed over inside the scan body (loop-invariant), invoked wherever the unit
references their ``shared_id``.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import blocks as B
from repro.models import layers as L
from repro.models.blocks import BlockCfg
from repro.models.layers import ParamCtx


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    vocab: int
    unit: tuple[BlockCfg, ...]
    n_repeats: int
    prologue: tuple[BlockCfg, ...] = ()
    epilogue: tuple[BlockCfg, ...] = ()
    shared: tuple[BlockCfg, ...] = ()
    input_kind: str = "tokens"          # "tokens" | "embeddings" | "mixed"
    n_prefix: int = 0                   # mixed: image/audio prefix length
    max_seq: int = 8192
    remat: str = "unit"                 # "none" | "unit"
    attn_chunk: int = 1024
    logit_softcap: float | None = None
    # scan_layers=False python-loops the unit repeats instead of lax.scan.
    # Production uses scan (O(1) HLO); the cost-faithful dry-run uses the
    # loop mode because XLA's cost_analysis counts while bodies ONCE
    # (see launch/dryrun.py --costmode and EXPERIMENTS.md §Roofline).
    scan_layers: bool = True

    @property
    def n_layers(self) -> int:
        return (
            len(self.prologue)
            + len(self.unit) * self.n_repeats
            + len(self.epilogue)
        )


def model_init(ctx: ParamCtx, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    params: dict = {
        "embed": L.embed_init(ctx, cfg.vocab, d),
        "final_ln": L.rmsnorm_init(ctx, d),
    }
    if cfg.prologue:
        params["prologue"] = [B.block_init(ctx, d, b) for b in cfg.prologue]
    params["unit"] = [
        ctx.stacked(cfg.n_repeats, functools.partial(B.block_init, d_model=d, blk=b))
        for b in cfg.unit
    ]
    if cfg.epilogue:
        params["epilogue"] = [B.block_init(ctx, d, b) for b in cfg.epilogue]
    if cfg.shared:
        params["shared"] = [B.block_init(ctx, d, b) for b in cfg.shared]
    return params


def init_params(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    return model_init(ParamCtx("init", key, dtype), cfg)


def abstract_params(cfg: ModelConfig, dtype=jnp.float32) -> dict:
    return model_init(ParamCtx("abstract", dtype=dtype), cfg)


def param_axes(cfg: ModelConfig) -> dict:
    return model_init(ParamCtx("axes"), cfg)


# ---------------------------------------------------------------------------
# Forward (training / prefill).
# ---------------------------------------------------------------------------


def _embed_inputs(params: dict, cfg: ModelConfig, inputs: dict, dtype) -> jax.Array:
    d = cfg.d_model
    scale = math.sqrt(d)
    if cfg.input_kind == "tokens":
        return L.embed_lookup(params["embed"], inputs["tokens"], dtype) * scale
    if cfg.input_kind == "embeddings":
        # audio/vision backbone-only: the modality frontend is a stub; the
        # harness provides precomputed frame/patch embeddings (brief §shapes).
        return inputs["embeddings"].astype(dtype)
    if cfg.input_kind == "mixed":
        txt = L.embed_lookup(params["embed"], inputs["tokens"], dtype) * scale
        return jnp.concatenate([inputs["prefix_embeddings"].astype(dtype), txt], axis=1)
    raise ValueError(cfg.input_kind)


def _apply_block_by_ref(params_blk, blk: BlockCfg, shared_params, x, positions, chunk):
    if blk.shared_id is not None:
        return B.block_apply(
            shared_params[blk.shared_id], blk, x, positions=positions, chunk=chunk
        )
    return B.block_apply(params_blk, blk, x, positions=positions, chunk=chunk)


def forward(
    params: dict, cfg: ModelConfig, inputs: dict, compute_dtype=jnp.bfloat16,
    shard=None,
) -> tuple[jax.Array, dict]:
    """-> (logits (B, T, vocab) fp32, aux losses).

    ``shard`` (optional ``repro.dist.sharding.ShardingCtx``): pins the
    activations' batch axis to the data mesh axes; parameters are expected
    to arrive committed to their own shardings (``ShardingCtx.place_params``).
    """
    h = _embed_inputs(params, cfg, inputs, compute_dtype)
    if shard is not None:
        h = shard.constrain(h, ("batch", None, "embed"))
    T = h.shape[1]
    positions = jnp.arange(T)[None, :]
    shared = params.get("shared", [])
    aux_total: dict = {}

    def add_aux(aux):
        for k, v in aux.items():
            aux_total[k] = aux_total.get(k, 0.0) + v

    for p_blk, blk in zip(params.get("prologue", []), cfg.prologue):
        h, aux = B.block_apply(p_blk, blk, h, positions=positions, chunk=cfg.attn_chunk)
        add_aux(aux)

    def unit_body(h_carry, rep_params):
        aux_rep: dict = {}
        for i, blk in enumerate(cfg.unit):
            h_carry, aux = _apply_block_by_ref(
                rep_params[i], blk, shared, h_carry, positions, cfg.attn_chunk
            )
            for k, v in aux.items():
                aux_rep[k] = aux_rep.get(k, 0.0) + v
        # pad aux to a fixed structure for scan
        keys = ("moe_lb_loss", "moe_z_loss", "moe_drop_frac")
        aux_vec = jnp.stack([jnp.asarray(aux_rep.get(k, 0.0), jnp.float32) for k in keys])
        return h_carry, aux_vec

    body = unit_body
    if cfg.remat == "unit":
        body = jax.checkpoint(unit_body, prevent_cse=False)
    if cfg.scan_layers:
        h, aux_vecs = jax.lax.scan(body, h, params["unit"])
    else:
        vecs = []
        for r in range(cfg.n_repeats):
            rep = jax.tree.map(lambda a: a[r], params["unit"])
            h, av = body(h, rep)
            vecs.append(av)
        aux_vecs = jnp.stack(vecs)
    for i, k in enumerate(("moe_lb_loss", "moe_z_loss", "moe_drop_frac")):
        s = aux_vecs[:, i].sum()
        add_aux({k: s})

    for p_blk, blk in zip(params.get("epilogue", []), cfg.epilogue):
        h, aux = B.block_apply(p_blk, blk, h, positions=positions, chunk=cfg.attn_chunk)
        add_aux(aux)

    h = L.rmsnorm(params["final_ln"], h)
    logits = L.unembed_logits(params["embed"], h)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    if shard is not None:
        logits = shard.constrain(logits, ("batch", None, "vocab"))
    return logits, aux_total


def lm_loss(
    params: dict,
    cfg: ModelConfig,
    batch: dict,
    compute_dtype=jnp.bfloat16,
    aux_weight: float = 0.01,
) -> tuple[jax.Array, dict]:
    """Next-token cross-entropy (+ MoE aux). ``batch['labels']`` aligns with
    the *token* positions (prefix positions carry label -100 = masked)."""
    logits, aux = forward(params, cfg, batch, compute_dtype)
    labels = batch["labels"]
    if cfg.input_kind == "mixed":
        pad = jnp.full(labels.shape[:1] + (cfg.n_prefix,), -100, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    mask = (labels >= 0).astype(jnp.float32)
    labels_c = jnp.clip(labels, 0, cfg.vocab - 1)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels_c[..., None], axis=-1)[..., 0]
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    metrics = {"loss": loss, "ntokens": mask.sum()}
    total = loss
    for k in ("moe_lb_loss", "moe_z_loss"):
        if k in aux:
            total = total + aux_weight * aux[k]
            metrics[k] = aux[k]
    if "moe_drop_frac" in aux:
        metrics["moe_drop_frac"] = aux["moe_drop_frac"]
    metrics["total_loss"] = total
    return total, metrics


# ---------------------------------------------------------------------------
# Decode (serving).
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16) -> dict:
    caches: dict = {}
    if cfg.prologue:
        caches["prologue"] = [
            B.block_init_cache(b, batch, max_seq, dtype) for b in cfg.prologue
        ]
    unit_caches = []
    for blk in cfg.unit:
        one = B.block_init_cache(blk, batch, max_seq, dtype)
        unit_caches.append(
            jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (cfg.n_repeats,) + a.shape).copy()
                if hasattr(a, "shape")
                else a,
                one,
            )
        )
    caches["unit"] = unit_caches
    if cfg.epilogue:
        caches["epilogue"] = [
            B.block_init_cache(b, batch, max_seq, dtype) for b in cfg.epilogue
        ]
    return caches


def model_supports_paging(cfg: ModelConfig) -> bool:
    """Every block must hold a full-attention GQA KV cache (DESIGN.md §3b)."""
    blks = cfg.prologue + cfg.unit + cfg.epilogue + cfg.shared
    return all(B.block_supports_paging(b) for b in blks)


def model_supports_speculative(cfg: ModelConfig) -> bool:
    """Speculative verify needs every block to accept a W-token window in
    one batch-shaped pass — the same full-attention GQA condition paging
    needs (ring buffers and recurrent states are sequential in the window
    dim), plus token inputs (the drafter re-embeds accepted tokens)."""
    return model_supports_paging(cfg) and cfg.input_kind == "tokens"


def model_kv_quant(cfg: ModelConfig) -> bool:
    """True if any attention block stores an int8-quantized KV cache."""
    blks = cfg.prologue + cfg.unit + cfg.epilogue + cfg.shared
    return any(b.attn is not None and b.attn.kv_quant for b in blks)


def init_paged_caches(
    cfg: ModelConfig, n_blocks: int, block_size: int, dtype=jnp.bfloat16
) -> dict:
    """Pool-shaped caches: one ``(n_blocks, block_size, ...)`` pool per
    layer, all layers addressed by the SAME physical block id (vLLM-style —
    one allocation covers a token's KV across the whole depth).  Structure
    mirrors :func:`init_caches` (unit pools stacked on the layers axis) so
    the decode scan machinery is unchanged."""
    if not model_supports_paging(cfg):
        raise NotImplementedError(
            f"{cfg.name}: paged KV needs full-attention GQA blocks throughout"
        )
    caches: dict = {}
    if cfg.prologue:
        caches["prologue"] = [
            B.block_init_paged_cache(b, n_blocks, block_size, dtype)
            for b in cfg.prologue
        ]
    unit_caches = []
    for blk in cfg.unit:
        one = B.block_init_paged_cache(blk, n_blocks, block_size, dtype)
        unit_caches.append(
            jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (cfg.n_repeats,) + a.shape).copy(),
                one,
            )
        )
    caches["unit"] = unit_caches
    if cfg.epilogue:
        caches["epilogue"] = [
            B.block_init_paged_cache(b, n_blocks, block_size, dtype)
            for b in cfg.epilogue
        ]
    return caches


def paged_cache_axes(cfg: ModelConfig) -> dict:
    """Logical-axes tree mirroring :func:`init_paged_caches` (the paged
    counterpart of :func:`cache_axes`): pools carry ``kv_blocks`` where the
    dense rows carried ``batch``/``seq_cache``."""
    from repro.models.layers import Axes

    axes: dict = {}
    if cfg.prologue:
        axes["prologue"] = [B.block_paged_cache_axes(b) for b in cfg.prologue]
    axes["unit"] = [
        jax.tree.map(
            lambda a: Axes(("layers",) + a.names),
            B.block_paged_cache_axes(b),
            is_leaf=lambda x: isinstance(x, Axes),
        )
        for b in cfg.unit
    ]
    if cfg.epilogue:
        axes["epilogue"] = [B.block_paged_cache_axes(b) for b in cfg.epilogue]
    return axes


def _leaf_names(table: dict, key: str, stacked: bool) -> tuple:
    """Logical axes of one pool/view leaf: attention's per-layout tables
    (``DENSE_CACHE_AXES`` for gathered views, ``POOL_CACHE_AXES`` for
    pools — one definition per layout), plus the stacked unit caches'
    leading 'layers' axis."""
    return (("layers",) if stacked else ()) + table[key]


def _map_paged_leaves(caches: dict, fn) -> dict:
    """Apply ``fn(key, leaf, stacked)`` over a paged-cache tree: unit pools
    carry a leading layers axis (``stacked=True``), prologue/epilogue don't."""
    out: dict = {}
    if "prologue" in caches:
        out["prologue"] = [
            {k: fn(k, a, False) for k, a in c.items()} for c in caches["prologue"]
        ]
    out["unit"] = [
        {k: fn(k, a, True) for k, a in c.items()} for c in caches["unit"]
    ]
    if "epilogue" in caches:
        out["epilogue"] = [
            {k: fn(k, a, False) for k, a in c.items()} for c in caches["epilogue"]
        ]
    return out


def paged_views(caches: dict, table: jax.Array, shard=None) -> dict:
    """Gather the logical dense view of every pool leaf: the result tree is
    shaped exactly like :func:`init_caches` (batch = table rows, seq =
    n_logical·block_size), so the UNCHANGED dense decode program runs on it.

    This is the engine's "shadow" read path (DESIGN.md §3b): gather ONCE
    per decode chunk, run the dense scan on the view, write the chunk's
    span back with :func:`writeback_paged_chunk` — amortizing the gather
    over ``chunk_steps`` instead of paying it every token.  The transient
    view costs ``slots x max_seq`` per layer (the dense *decode-batch*
    footprint; the pool remains the only persistent KV store).  With
    ``shard`` the gathered view is constraint-pinned to the dense cache
    shardings (batch on ``data``, kv_heads on ``model``)."""
    from repro.kernels.paged_gather import gather_blocks

    def leaf(key, pool, stacked):
        if stacked:
            v = jax.vmap(lambda p: gather_blocks(p, table))(pool)
        else:
            v = gather_blocks(pool, table)
        if shard is not None:
            v = shard.constrain(v, _leaf_names(A.DENSE_CACHE_AXES, key, stacked))
        return v

    return _map_paged_leaves(caches, leaf)


def writeback_paged_chunk(
    caches: dict, view: dict, table: jax.Array, pos0: jax.Array, steps: int,
    shard=None,
) -> dict:
    """Scatter a finished chunk's writes from the dense shadow ``view``
    back into the pools.

    The dense scan wrote rows only at positions ``pos0[b] .. pos0[b] +
    steps - 1`` (latched rows rewrite their frozen slot; untouched
    positions in that window still hold the gathered pool values, so
    copying them back is an exact no-op).  Out-of-span positions (chunk
    overrun past ``max_seq``) are redirected to the sentinel block,
    mirroring the per-step write path."""

    from repro.models.attention import paged_route

    def write(pool, v):
        bs = pool.shape[1]
        B, S = v.shape[:2]
        positions = pos0[:, None] + jnp.arange(steps)[None, :]   # (B, steps)
        pos_cl = jnp.minimum(positions, S - 1)                   # view read idx
        rest = v.ndim - 2
        idx = pos_cl.reshape((B, steps) + (1,) * rest)
        vals = jnp.take_along_axis(v, idx, axis=1)               # (B,steps,...)
        phys, off = paged_route(table, positions, bs)
        return pool.at[phys, off].set(vals.astype(pool.dtype))

    def leaf(key, pool, v, stacked):
        out = jax.vmap(write)(pool, v) if stacked else write(pool, v)
        if shard is not None:
            out = shard.constrain(out, _leaf_names(A.POOL_CACHE_AXES, key, stacked))
        return out

    pooled = _map_paged_leaves(caches, lambda k, a, s: (k, a, s))
    return jax.tree.map(
        lambda ps, v: leaf(ps[0], ps[1], v, ps[2]),
        pooled, view,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def copy_paged_blocks(caches: dict, srcs, dsts, shard=None) -> dict:
    """Device-side copy of physical blocks ``srcs[i] -> dsts[i]`` in every
    pool leaf — the data half of copy-on-write
    (``kv_pool.BlockPool.copy_on_write`` rebinds the table; this copies the
    KV payload).  The whole batch of copies lowers to ONE gather + ONE
    scatter per leaf, so an admission wave's CoW copies cost two dispatches
    per leaf instead of ``2n`` dynamic slices (the ROADMAP "sharded
    prefix-cache block copies" note: under a mesh the batched scatter keeps
    the pool's ``kv_blocks`` sharding with a single collective round).

    ``srcs``/``dsts`` are length-``n`` int32 vectors (traced OK — one jitted
    program serves every same-``n`` wave; callers bucket by wave size).
    ``dsts`` must be pairwise distinct: duplicate scatter targets apply in
    unspecified order.  The pool allocator guarantees this — freshly
    CoW-allocated blocks are unique by construction."""
    srcs = jnp.reshape(jnp.asarray(srcs, jnp.int32), (-1,))
    dsts = jnp.reshape(jnp.asarray(dsts, jnp.int32), (-1,))

    def copy_leaf(key, pool, stacked: bool):
        # unit pools carry a leading layers axis, so their block axis is 1;
        # prologue/epilogue pools index blocks at axis 0
        ax = 1 if stacked else 0
        blks = jnp.take(pool, srcs, axis=ax)
        out = pool.at[:, dsts].set(blks) if stacked else pool.at[dsts].set(blks)
        if shard is not None:
            out = shard.constrain(out, _leaf_names(A.POOL_CACHE_AXES, key, stacked))
        return out

    return _map_paged_leaves(caches, copy_leaf)


def copy_paged_block(caches: dict, src, dst, shard=None) -> dict:
    """Single-pair :func:`copy_paged_blocks` (kept for the fork/beam-search
    CoW primitive's call sites and tests)."""
    return copy_paged_blocks(
        caches, jnp.reshape(jnp.asarray(src, jnp.int32), (1,)),
        jnp.reshape(jnp.asarray(dst, jnp.int32), (1,)), shard,
    )


def prefill(
    params: dict,
    cfg: ModelConfig,
    inputs: dict,
    max_seq: int,
    compute_dtype=jnp.bfloat16,
    shard=None,
) -> tuple[jax.Array, dict]:
    """Inference prefill: full-sequence forward that also fills the decode
    caches (the ``prefill_32k`` workload). Returns (logits, caches).

    ``shard`` (optional ``ShardingCtx``) pins every produced cache leaf to
    its logical-axes sharding (kv_heads on ``model``, batch/seq on the data
    axes), so a sharded serve program hands decode a distributed cache."""
    h = _embed_inputs(params, cfg, inputs, compute_dtype)
    if shard is not None:
        h = shard.constrain(h, ("batch", None, "embed"))
    T = h.shape[1]
    positions = jnp.arange(T)[None, :]
    shared = params.get("shared", [])
    caches: dict = {}

    if cfg.prologue:
        pcs = []
        for p_blk, blk in zip(params["prologue"], cfg.prologue):
            h, c = B.block_prefill(
                p_blk, blk, h, positions=positions, max_seq=max_seq,
                chunk=cfg.attn_chunk, shard=shard,
            )
            pcs.append(c)
        caches["prologue"] = pcs

    def unit_body(h_carry, rep_params):
        new_caches = []
        for i, blk in enumerate(cfg.unit):
            p = shared[blk.shared_id] if blk.shared_id is not None else rep_params[i]
            h_carry, c = B.block_prefill(
                p, blk, h_carry, positions=positions, max_seq=max_seq,
                chunk=cfg.attn_chunk, shard=shard,
            )
            new_caches.append(c)
        return h_carry, new_caches

    if cfg.scan_layers:
        h, unit_caches = jax.lax.scan(unit_body, h, params["unit"])
    else:
        reps = []
        for r in range(cfg.n_repeats):
            rep = jax.tree.map(lambda a: a[r], params["unit"])
            h, cs = unit_body(h, rep)
            reps.append(cs)
        unit_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
    caches["unit"] = unit_caches

    if cfg.epilogue:
        ecs = []
        for p_blk, blk in zip(params["epilogue"], cfg.epilogue):
            h, c = B.block_prefill(
                p_blk, blk, h, positions=positions, max_seq=max_seq,
                chunk=cfg.attn_chunk, shard=shard,
            )
            ecs.append(c)
        caches["epilogue"] = ecs

    h = L.rmsnorm(params["final_ln"], h)
    logits = L.unembed_logits(params["embed"], h)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits, caches


def cache_axes(cfg: ModelConfig) -> dict:
    """Logical-axes tree mirroring :func:`init_caches` (stacked unit caches
    get a leading 'layers' axis)."""
    from repro.models.layers import Axes

    axes: dict = {}
    if cfg.prologue:
        axes["prologue"] = [B.block_cache_axes(b) for b in cfg.prologue]
    axes["unit"] = [
        jax.tree.map(
            lambda a: Axes(("layers",) + a.names),
            B.block_cache_axes(b),
            is_leaf=lambda x: isinstance(x, Axes),
        )
        for b in cfg.unit
    ]
    if cfg.epilogue:
        axes["epilogue"] = [B.block_cache_axes(b) for b in cfg.epilogue]
    return axes


def abstract_caches(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_caches(cfg, batch, max_seq, dtype))


def insert_cache_slot(cfg: ModelConfig, caches: dict, one: dict, slot,
                      shard=None) -> dict:
    """Write a batch-1 cache tree into batch row ``slot`` of a live cache.

    ``one`` must mirror ``caches`` structurally with batch size 1 (both
    built for the same ``max_seq``, e.g. by :func:`prefill` vs
    :func:`init_caches`).  The batch axis of each leaf is located by name
    via :func:`cache_axes` — stacked unit caches carry a leading 'layers'
    axis, so the batch axis is not a fixed position.  ``slot`` may be a
    traced scalar: the write lowers to one dynamic_update_slice per leaf,
    so a single jitted program serves every slot.
    """
    axes = cache_axes(cfg)

    def put(big, small, ax):
        b_axis = ax.names.index("batch")
        out = jax.lax.dynamic_update_slice_in_dim(
            big, small.astype(big.dtype), slot, axis=b_axis
        )
        if shard is not None:
            out = shard.constrain(out, ax.names)
        return out

    return jax.tree.map(put, caches, one, axes)


def prefill_into_slot(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,           # (1, T_pad) int32, right-padded prompt
    length: jax.Array,           # scalar int32: true prompt length (>= 1)
    slot,                        # scalar int32: target batch row
    caches: dict,
    max_seq: int,
    compute_dtype=jnp.bfloat16,
    shard=None,
) -> tuple[jax.Array, dict]:
    """Prefill ONE request and splice its KV into slot ``slot`` of a live
    batch cache — the cache-insert primitive continuous batching needs to
    swap a finished row for a queued request between decode chunks.

    Returns ``(last_logits (vocab,) fp32, caches)`` where ``last_logits``
    is taken at the request's own last real token (position ``length-1``;
    causal attention makes it independent of the right-padding, which is
    what keeps slot-admitted generations bit-identical to solo
    :class:`~repro.serve.engine.Engine` ``generate`` calls).  Jit callers
    retrace once per padded prompt length ``T_pad`` (bucket prompts to
    bound compiles); ``length`` and ``slot`` stay traced.  Thin k=1 wrapper
    over :func:`prefill_into_slots` — the serve loop uses the grouped form
    because slots free in bursts at chunk boundaries.
    """
    last, caches = prefill_into_slots(
        params, cfg, tokens,
        jnp.reshape(jnp.asarray(length, jnp.int32), (1,)),
        jnp.reshape(jnp.asarray(slot, jnp.int32), (1,)),
        caches, max_seq, compute_dtype, shard,
    )
    return last[0], caches


def prefill_into_slots(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,           # (k, T_pad) int32, right-padded prompts
    lengths: jax.Array,          # (k,) int32 true prompt lengths
    slots: jax.Array,            # (k,) int32 target batch rows
    caches: dict,
    max_seq: int,
    compute_dtype=jnp.bfloat16,
    shard=None,
) -> tuple[jax.Array, dict]:
    """Batched :func:`prefill_into_slot`: ONE prefill dispatch admits ``k``
    queued requests at once (k is static — jit callers retrace per
    ``(k, T_pad)`` admission-group shape).  Continuous batching frees slots
    in bursts at chunk boundaries, so grouped admission amortizes the
    prefill dispatch overhead that dominates one-at-a-time slot refills.
    Row independence of prefill makes each admitted row bit-identical to
    its batch-1 admission.  Returns ``(last_logits (k, vocab), caches)``.
    """
    k = tokens.shape[0]
    logits, many = prefill(
        params, cfg, {"tokens": tokens}, max_seq, compute_dtype, shard
    )
    last = jnp.take_along_axis(
        logits, (lengths - 1)[:, None, None], axis=1
    )[:, 0]
    axes = cache_axes(cfg)
    for i in range(k):
        one = jax.tree.map(
            lambda a, ax: jax.lax.dynamic_slice_in_dim(
                a, i, 1, axis=ax.names.index("batch")
            ),
            many, axes,
        )
        caches = insert_cache_slot(cfg, caches, one, slots[i], shard)
    return last, caches


def prefill_into_pages(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,           # (k, Ts_pad) int32, right-padded SUFFIX tokens
    lengths: jax.Array,          # (k,) int32 true TOTAL prompt lengths
    tables: jax.Array,           # (k, n_logical) int32 block tables
    caches: dict,                # paged pools (init_paged_caches)
    start,                       # scalar int32: first uncached position
    compute_dtype=jnp.bfloat16,
    view_blocks: int | None = None,   # STATIC attention-view truncation:
                                      # table columns covering start + Ts
                                      # (bit-identical — see attn_prefill_paged)
    shard=None,
) -> tuple[jax.Array, dict]:
    """Paged admission prefill: compute ONLY the uncached suffix (positions
    ``start .. len-1``; a prefix-cache hit makes ``start > 0``) and scatter
    its K/V into the pool blocks mapped by ``tables``.

    The paged counterpart of :func:`prefill_into_slots` — no private cache
    row is built or spliced; blocks are written in place.  Returns
    ``(last_logits (k, vocab), caches)`` with ``last_logits`` taken at each
    request's last real token (row ``lengths - 1 - start`` of the suffix).
    Jit callers retrace once per ``(k, Ts_pad)`` group shape; ``lengths``,
    ``tables`` and ``start`` stay traced (admission groups bucket by
    ``(start, Ts_pad)``).  Bit-identity to the dense path: suffix K/V and
    logits are computed by the same per-position math
    (``attention._project_qkv`` / ``flash_attention`` with exact no-op
    masked chunks — see ``attn_prefill_paged``), and under ``kv_quant`` the
    engine forces ``start = 0`` so prefill attention sees raw values
    exactly like the dense path.
    """
    k, Ts = tokens.shape
    start = jnp.asarray(start, jnp.int32)
    h = _embed_inputs(params, cfg, {"tokens": tokens}, compute_dtype)
    positions = start + jnp.arange(Ts)[None, :]
    shared = params.get("shared", [])
    new_caches: dict = {}

    def apply(p_blk, blk, h, cache):
        p = shared[blk.shared_id] if blk.shared_id is not None else p_blk
        return B.block_prefill_paged(
            p, blk, h, positions=positions, cache=cache, table=tables,
            lengths=lengths, start=start, chunk=cfg.attn_chunk,
            view_blocks=view_blocks, shard=shard,
        )

    if cfg.prologue:
        pcs = []
        for p_blk, blk, c in zip(params["prologue"], cfg.prologue, caches["prologue"]):
            h, c2 = apply(p_blk, blk, h, c)
            pcs.append(c2)
        new_caches["prologue"] = pcs

    def unit_body(h_carry, xs):
        rep_params, rep_caches = xs
        new_rep = []
        for i, blk in enumerate(cfg.unit):
            h_carry, c2 = apply(rep_params[i], blk, h_carry, rep_caches[i])
            new_rep.append(c2)
        return h_carry, new_rep

    if cfg.scan_layers:
        h, new_unit = jax.lax.scan(unit_body, h, (params["unit"], caches["unit"]))
    else:
        reps = []
        for r in range(cfg.n_repeats):
            rep_p = jax.tree.map(lambda a: a[r], params["unit"])
            rep_c = jax.tree.map(lambda a: a[r], caches["unit"])
            h, nc = unit_body(h, (rep_p, rep_c))
            reps.append(nc)
        new_unit = jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
    new_caches["unit"] = new_unit

    if cfg.epilogue:
        ecs = []
        for p_blk, blk, c in zip(params["epilogue"], cfg.epilogue, caches["epilogue"]):
            h, c2 = apply(p_blk, blk, h, c)
            ecs.append(c2)
        new_caches["epilogue"] = ecs

    h = L.rmsnorm(params["final_ln"], h)
    logits = L.unembed_logits(params["embed"], h)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    last = jnp.take_along_axis(
        logits, (lengths - 1 - start)[:, None, None], axis=1
    )[:, 0]
    return last, new_caches


def decode_step(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,           # (B, 1) int32 (or (B,1,d) embeddings)
    caches: dict,
    pos: jax.Array,              # (B,)
    compute_dtype=jnp.bfloat16,
    table: jax.Array | None = None,   # (B, n_logical): paged block tables
    shard=None,
) -> tuple[jax.Array, dict]:
    """One decode step for the whole model -> (logits (B, vocab), caches).

    With ``table`` set, ``caches`` holds paged pools
    (:func:`init_paged_caches`) and every block reads/writes through the
    block table (DESIGN.md §3b); the same physical block id addresses every
    layer's pool.  ``shard`` (optional ``ShardingCtx``) keeps the updated
    cache leaves pinned to their mesh shardings step over step."""
    d = cfg.d_model
    if cfg.input_kind == "tokens" or cfg.input_kind == "mixed":
        h = L.embed_lookup(params["embed"], tokens, compute_dtype) * math.sqrt(d)
    else:
        h = tokens.astype(compute_dtype)
        if h.ndim == 2:  # allow (B, d)
            h = h[:, None]
    shared = params.get("shared", [])
    new_caches: dict = {}

    if cfg.prologue:
        ncs = []
        for p_blk, blk, c in zip(params["prologue"], cfg.prologue, caches["prologue"]):
            h, c2 = B.block_decode_step(p_blk, blk, h, c, pos, table, shard)
            ncs.append(c2)
        new_caches["prologue"] = ncs

    def unit_body(carry, xs):
        h_c = carry
        rep_params, rep_caches = xs
        new_rep = []
        for i, blk in enumerate(cfg.unit):
            p = shared[blk.shared_id] if blk.shared_id is not None else rep_params[i]
            h_c, c2 = B.block_decode_step(
                p, blk, h_c, rep_caches[i], pos, table, shard
            )
            new_rep.append(c2)
        return h_c, new_rep

    if cfg.scan_layers:
        h, new_unit = jax.lax.scan(unit_body, h, (params["unit"], caches["unit"]))
    else:
        reps = []
        for r in range(cfg.n_repeats):
            rep_p = jax.tree.map(lambda a: a[r], params["unit"])
            rep_c = jax.tree.map(lambda a: a[r], caches["unit"])
            h, nc = unit_body(h, (rep_p, rep_c))
            reps.append(nc)
        new_unit = jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
    new_caches["unit"] = new_unit

    if cfg.epilogue:
        ncs = []
        for p_blk, blk, c in zip(params["epilogue"], cfg.epilogue, caches["epilogue"]):
            h, c2 = B.block_decode_step(p_blk, blk, h, c, pos, table, shard)
            ncs.append(c2)
        new_caches["epilogue"] = ncs

    h = L.rmsnorm(params["final_ln"], h)
    logits = L.unembed_logits(params["embed"], h)[:, 0]
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits, new_caches


def verify_window(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,           # (B, W) int32: last accepted token + k drafts
    caches: dict,
    pos: jax.Array,              # (B,) window start positions
    compute_dtype=jnp.bfloat16,
    table: jax.Array | None = None,   # (B, n_logical): paged block tables
    shard=None,
) -> tuple[jax.Array, dict]:
    """Speculative verification: score all ``W = k + 1`` window positions of
    every row in ONE pass -> ``(logits (B, W, vocab) fp32, caches)``.

    ``logits[:, j]`` is the next-token distribution after the token at
    absolute position ``pos + j`` — exactly what ``decode_step`` would
    return at step ``j`` of a sequential chunk, provided the window prefix
    matches the sequential stream (the acceptance rule's induction,
    ``serve/speculative.py``).  Structure mirrors :func:`decode_step`
    (scan-over-repeats on the same stacked caches) with
    :func:`~repro.models.blocks.block_verify_window` per block; the per-row
    accepted length is applied by the CALLER — the model writes all W
    positions and the engine's rollback invariants make rejected writes
    unobservable (DESIGN.md §9)."""
    if not model_supports_speculative(cfg):
        raise NotImplementedError(
            f"{cfg.name}: speculative verify needs token-input full-attention "
            "GQA blocks throughout"
        )
    d = cfg.d_model
    h = L.embed_lookup(params["embed"], tokens, compute_dtype) * math.sqrt(d)
    shared = params.get("shared", [])
    new_caches: dict = {}

    if cfg.prologue:
        ncs = []
        for p_blk, blk, c in zip(params["prologue"], cfg.prologue, caches["prologue"]):
            h, c2 = B.block_verify_window(p_blk, blk, h, c, pos, table, shard)
            ncs.append(c2)
        new_caches["prologue"] = ncs

    def unit_body(carry, xs):
        h_c = carry
        rep_params, rep_caches = xs
        new_rep = []
        for i, blk in enumerate(cfg.unit):
            p = shared[blk.shared_id] if blk.shared_id is not None else rep_params[i]
            h_c, c2 = B.block_verify_window(
                p, blk, h_c, rep_caches[i], pos, table, shard
            )
            new_rep.append(c2)
        return h_c, new_rep

    if cfg.scan_layers:
        h, new_unit = jax.lax.scan(unit_body, h, (params["unit"], caches["unit"]))
    else:
        reps = []
        for r in range(cfg.n_repeats):
            rep_p = jax.tree.map(lambda a: a[r], params["unit"])
            rep_c = jax.tree.map(lambda a: a[r], caches["unit"])
            h, nc = unit_body(h, (rep_p, rep_c))
            reps.append(nc)
        new_unit = jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
    new_caches["unit"] = new_unit

    if cfg.epilogue:
        ncs = []
        for p_blk, blk, c in zip(params["epilogue"], cfg.epilogue, caches["epilogue"]):
            h, c2 = B.block_verify_window(p_blk, blk, h, c, pos, table, shard)
            ncs.append(c2)
        new_caches["epilogue"] = ncs

    h = L.rmsnorm(params["final_ln"], h)
    logits = L.unembed_logits(params["embed"], h)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits, new_caches
