"""Data pipelines: deterministic synthetic LM tokens, regression sets, and
an MNIST-like classification set (offline container: no downloads — the
MNIST-like set is class-conditional structured noise; accuracy numbers on it
are labelled as synthetic in EXPERIMENTS.md).

Determinism & fault tolerance: every batch is a pure function of
``(seed, step)``, so a restart at step N reproduces the exact stream without
replaying — the checkpoint only needs to store the step counter
(DESIGN.md §4)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def lm_batch(cfg: LMDataConfig, step: int) -> dict:
    """Markov-chain synthetic tokens (learnable structure, not iid noise)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k1, k2 = jax.random.split(key)
    B, T, V = cfg.global_batch, cfg.seq_len, cfg.vocab
    # block-structured transitions: next ~ (prev * a + noise) mod V
    start = jax.random.randint(k1, (B, 1), 0, V)
    steps = jax.random.randint(k2, (B, T), 0, 7)
    toks = (start + jnp.cumsum(steps, axis=1)) % V
    tokens = toks.astype(jnp.int32)
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full((B, 1), -100, jnp.int32)], axis=1
    )
    return {"tokens": tokens, "labels": labels}


def mnist_like(
    n: int, seed: int = 0, n_classes: int = 10, dim: int = 784,
    noise: float = 0.7, proto_seed: int = 1234,
) -> tuple[np.ndarray, np.ndarray]:
    """Class-conditional structured patterns in [-1, 1]^784 (SYNTHETIC MNIST
    stand-in: offline container). Prototypes are smooth random fields; inputs
    are prototype + noise, so the task needs a real decision boundary.

    ``proto_seed`` fixes the class prototypes INDEPENDENTLY of the sampling
    seed, so train/test splits drawn with different seeds share one task."""
    rs_p = np.random.RandomState(proto_seed)
    side = int(np.sqrt(dim))
    protos = []
    for c in range(n_classes):
        f = rs_p.normal(size=(side // 4 + 1, side // 4 + 1))
        up = np.kron(f, np.ones((4, 4)))[:side, :side]
        protos.append(up / (np.abs(up).max() + 1e-9))
    protos = np.stack(protos).reshape(n_classes, -1)
    rs = np.random.RandomState(seed)
    y = rs.randint(0, n_classes, n)
    x = protos[y] + noise * rs.normal(size=(n, dim))
    x = np.tanh(x).astype(np.float32)
    return x, y.astype(np.int32)


def regression_set(n: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """The KAN paper's flavour of symbolic targets: f(x,y)=exp(sin(pi x)+y^2)."""
    rs = np.random.RandomState(seed)
    X = rs.uniform(-1, 1, (n, 2)).astype(np.float32)
    Y = np.exp(np.sin(np.pi * X[:, :1]) + X[:, 1:] ** 2).astype(np.float32)
    return X, Y
