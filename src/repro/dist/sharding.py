"""Logical-axis -> PartitionSpec rules (DESIGN.md §4).

Parameters, optimizer states and decode caches all carry an :class:`Axes`
leaf naming the *logical* role of every dimension (``embed``, ``ffn``,
``heads``, ``vocab``, ``batch``, ``seq_cache``, ...).  This module turns
those names into ``PartitionSpec``s for a concrete mesh:

* the tensor-parallel ``model`` mesh axis goes to the highest-priority
  logical axis (vocab > experts > ffn > heads > kv_heads > kv_lora > embed)
  whose size divides the mesh axis — the standard Megatron-style placement
  (shard the widest, most parallel dimension; fall back when it doesn't
  divide);
* the data-parallel axes (``pod`` + ``data``) go to ``batch``; when the
  batch cannot occupy them (long-context decode with B=1), the KV cache's
  ``seq_cache`` dimension takes them instead;
* ``layers`` (the scan-over-repeats stacking axis) and anonymous ``None``
  axes are never sharded;
* :func:`zero_spec` adds the data axes to an otherwise-replicated dimension
  — ZeRO-style optimizer-state sharding on top of the parameter spec;
* the paged KV pool's ``kv_blocks`` axis takes the data axes (pools carry
  no ``batch``/``seq_cache``), while ``kv_heads`` still takes ``model`` —
  each DP shard holds a slice of the physical block pool;
* :class:`ShardingCtx` bundles a mesh with these rules so serving call
  sites (``models/lm.py``, ``serve/engine.py``) stop re-deriving specs.

Every rule degrades to replication when divisibility fails, so the same
model code lowers on a 1-device host mesh and a 512-chip production mesh.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.models.layers import Axes

# Highest-priority first: which logical axis takes the tensor-parallel mesh
# axis.  vocab first (embedding/unembed are the largest matrices), then the
# expert and FFN dims (pure column/row parallelism), then attention heads.
MODEL_AXIS_PRIORITY = (
    "vocab", "experts", "ffn", "heads", "kv_heads", "kv_lora", "embed",
)

# Mesh axes that carry data parallelism, outermost first.
DATA_MESH_AXES = ("pod", "data")

# Logical axes that may absorb the data-parallel mesh axes, in order of
# preference.  ``kv_blocks`` is the paged KV pool's block axis (the paged
# analogue of a dense cache's slots × sequence): pools have no ``batch``
# or ``seq_cache`` dimension, so the block axis takes the data axes —
# each DP shard holds a slice of the physical block pool while the
# ``model`` axis splits ``kv_heads`` exactly as it does dense rows.
BATCH_AXIS_PRIORITY = ("batch", "seq_cache", "kv_blocks")


def _mesh_axis_size(mesh, name: str) -> int:
    return int(mesh.shape[name]) if name in mesh.shape else 0


def _data_axis_combos(mesh) -> list[tuple[str, ...]]:
    """Candidate data-axis assignments, largest first: ("pod","data") ->
    ("data",) -> ("pod",)."""
    present = tuple(a for a in DATA_MESH_AXES if a in mesh.shape)
    combos: list[tuple[str, ...]] = []
    if len(present) > 1:
        combos.append(present)
    for a in present[::-1] if len(present) > 1 else present:
        combos.append((a,))
    # dedupe, preserve order
    seen: set[tuple[str, ...]] = set()
    out = []
    for c in combos:
        if c not in seen:
            seen.add(c)
            out.append(c)
    return out


def _combo_size(mesh, combo: tuple[str, ...]) -> int:
    return math.prod(_mesh_axis_size(mesh, a) for a in combo)


def spec_for(axes: Axes, shape: tuple[int, ...], mesh) -> PartitionSpec:
    """PartitionSpec for one tensor from its logical axes + shape.

    Divisibility fallback: a mesh axis is only assigned to a dimension whose
    size it divides; otherwise the next candidate dimension (or replication)
    is used.  Each mesh axis is used at most once per spec.
    """
    assert len(axes.names) == len(shape), (axes, shape)
    entries: list = [None] * len(shape)

    # --- tensor parallelism: the "model" mesh axis -----------------------
    msize = _mesh_axis_size(mesh, "model")
    for logical in MODEL_AXIS_PRIORITY:
        placed = False
        for i, name in enumerate(axes.names):
            if name == logical and msize and shape[i] % msize == 0:
                entries[i] = "model"
                placed = True
                break
        if placed:
            break

    # --- data parallelism: batch (or seq_cache) takes pod+data -----------
    for logical in BATCH_AXIS_PRIORITY:
        placed = False
        for i, name in enumerate(axes.names):
            if name != logical or entries[i] is not None:
                continue
            for combo in _data_axis_combos(mesh):
                cs = _combo_size(mesh, combo)
                if cs and shape[i] % cs == 0:
                    entries[i] = combo if len(combo) > 1 else combo[0]
                    placed = True
                    break
            if placed:
                break
        if placed:
            break

    return PartitionSpec(*entries)


def zero_spec(base: PartitionSpec, shape: tuple[int, ...], mesh) -> PartitionSpec:
    """ZeRO: add the data-parallel axes to the first replicated dimension of
    ``base`` that they divide (optimizer m/v/master shards over DP ranks).

    Falls back to ``base`` unchanged when nothing divides — a 1-device host
    mesh then simply replicates, which is correct if wasteful.
    """
    entries = list(base) + [None] * (len(shape) - len(base))
    used = {a for e in entries for a in ((e,) if isinstance(e, str) else (e or ()))}
    for combo in _data_axis_combos(mesh):
        if any(a in used for a in combo):
            continue
        cs = _combo_size(mesh, combo)
        if not cs:
            continue
        for i, e in enumerate(entries):
            if e is None and shape[i] % cs == 0:
                entries[i] = combo if len(combo) > 1 else combo[0]
                return PartitionSpec(*entries)
    return PartitionSpec(*entries)


def batch_spec(mesh, batch: int) -> PartitionSpec:
    """Spec whose first entry shards the global batch over the data axes
    (largest divisible combination; None when nothing divides)."""
    for combo in _data_axis_combos(mesh):
        cs = _combo_size(mesh, combo)
        if cs and batch % cs == 0:
            return PartitionSpec(combo if len(combo) > 1 else combo[0])
    return PartitionSpec(None)


def _is_axes(x) -> bool:
    return isinstance(x, Axes)


def tree_shardings(axes_tree, abstract_tree, mesh):
    """NamedSharding tree: one leaf per (Axes, ShapeDtypeStruct) pair."""
    return jax.tree.map(
        lambda a, s: NamedSharding(mesh, spec_for(a, s.shape, mesh)),
        axes_tree,
        abstract_tree,
        is_leaf=_is_axes,
    )


def tree_zero_shardings(axes_tree, abstract_tree, mesh):
    """ZeRO-sharded variant of :func:`tree_shardings` (optimizer states)."""
    return jax.tree.map(
        lambda a, s: NamedSharding(
            mesh, zero_spec(spec_for(a, s.shape, mesh), s.shape, mesh)
        ),
        axes_tree,
        abstract_tree,
        is_leaf=_is_axes,
    )


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    """Mesh + spec derivation, bundled for the serving stack.

    Inference entry points (``models/lm.py`` ``prefill``/``decode_step``/
    the paged variants and ``serve/engine.py``) take an optional
    ``ShardingCtx`` instead of re-deriving PartitionSpecs at every call
    site: the ctx owns the mesh and turns logical axis names into
    ``NamedSharding``s / ``with_sharding_constraint``s on demand.  Every
    spec degrades to replication when divisibility fails, so a 1-device
    mesh ctx is a behavioral no-op (bit-identical programs) and the same
    serving code lowers on a laptop and a pod slice.
    """

    mesh: object                     # jax.sharding.Mesh

    @property
    def n_devices(self) -> int:
        return int(self.mesh.size)

    # -- spec derivation ------------------------------------------------

    def spec(self, names: tuple, shape: tuple[int, ...]) -> PartitionSpec:
        return spec_for(Axes(tuple(names)), tuple(shape), self.mesh)

    def named(self, names: tuple, shape: tuple[int, ...]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(names, shape))

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, PartitionSpec())

    def rows(self, batch: int) -> NamedSharding:
        """Sharding for a leading-batch host array (tokens, positions)."""
        return NamedSharding(self.mesh, batch_spec(self.mesh, batch))

    # -- constraints (used inside jitted model code) --------------------

    def constrain(self, x, names: tuple):
        """Pin one traced array to its logical-axes spec."""
        return jax.lax.with_sharding_constraint(x, self.named(names, x.shape))

    def constrain_tree(self, tree, axes_tree):
        """Pin a whole tree (caches, params) to its Axes tree's specs —
        the guard that keeps KV updates from silently gathering."""
        return jax.tree.map(
            lambda ax, a: jax.lax.with_sharding_constraint(
                a, NamedSharding(self.mesh, spec_for(ax, a.shape, self.mesh))
            ),
            axes_tree,
            tree,
            is_leaf=_is_axes,
        )

    # -- model-level sharding trees (lazy lm import: no cycle) ----------

    def param_shardings(self, model_cfg, dtype=jnp.float32):
        from repro.models import lm

        return tree_shardings(
            lm.param_axes(model_cfg), lm.abstract_params(model_cfg, dtype),
            self.mesh,
        )

    def place_params(self, model_cfg, params):
        """device_put the parameter tree onto its derived shardings."""
        return shard_tree(params, self.param_shardings(model_cfg))

    def cache_shardings(self, model_cfg, batch: int, max_seq: int,
                        dtype=jnp.bfloat16):
        from repro.models import lm

        return tree_shardings(
            lm.cache_axes(model_cfg),
            lm.abstract_caches(model_cfg, batch, max_seq, dtype),
            self.mesh,
        )

    def paged_cache_shardings(self, model_cfg, n_blocks: int,
                              block_size: int, dtype=jnp.bfloat16):
        from repro.models import lm

        abstract = jax.eval_shape(
            lambda: lm.init_paged_caches(model_cfg, n_blocks, block_size, dtype)
        )
        return tree_shardings(lm.paged_cache_axes(model_cfg), abstract, self.mesh)


def with_sharded_leaves(abstract_tree, sharding_tree):
    """Attach shardings to a ShapeDtypeStruct tree (jit.lower() inputs)."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        abstract_tree,
        sharding_tree,
    )


def shard_tree(tree, sharding_tree):
    """device_put every leaf onto its sharding (used by launchers)."""
    return jax.tree.map(jax.device_put, tree, sharding_tree)
