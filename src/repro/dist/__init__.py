"""Distributed substrate: logical-axis sharding rules and gradient
compression (DESIGN.md §4).

``sharding`` maps the :class:`repro.models.layers.Axes` trees produced by
``ParamCtx(mode="axes")`` onto concrete ``PartitionSpec``s for whatever mesh
the host offers; ``compression`` models the wire formats used for gradient
all-reduces (bf16 / int8).
"""

from repro.dist import compression, sharding

__all__ = ["compression", "sharding"]
