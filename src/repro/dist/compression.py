"""Gradient wire-format compression (DESIGN.md §4).

Under data parallelism the gradient all-reduce is the dominant inter-pod
traffic; compressing the wire format halves (bf16) or quarters (int8) the
bytes on the slow links.  ``compress_tree`` models this as a
compress->decompress round trip: the returned tree is float32 again (the
optimizer is agnostic), carrying exactly the quantization error the wire
format would introduce.

int8 uses per-tensor symmetric scaling (q = round(g / s), s = max|g|/127),
matching the coefficient scheme of ``repro.core.quantization``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

KINDS = ("bf16", "int8")


def _roundtrip_bf16(g: jax.Array) -> jax.Array:
    return g.astype(jnp.bfloat16).astype(jnp.float32)


def _roundtrip_int8(g: jax.Array) -> jax.Array:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def compress_leaf(g: jax.Array, kind: str) -> jax.Array:
    if kind == "bf16":
        return _roundtrip_bf16(g)
    if kind == "int8":
        return _roundtrip_int8(g)
    raise ValueError(f"unknown compression kind {kind!r}; expected {KINDS}")


def compress_tree(grads, kind: str):
    """Round-trip a gradient tree through the given wire format."""
    return jax.tree.map(lambda g: compress_leaf(g, kind), grads)


def wire_bytes(grads, kind: str | None) -> int:
    """Modeled all-reduce payload bytes for a gradient tree."""
    per = {None: 4, "bf16": 2, "int8": 1}[kind]
    return sum(leaf.size * per for leaf in jax.tree.leaves(grads))
