"""Fault-tolerant checkpointing (no orbax on the box — hand-built).

Layout:  <dir>/step_<N>/
            manifest.json     (step, tree structure, shapes, dtypes, done flag)
            arrays.npz        (flat leaf arrays, key = tree path)

Guarantees:
* **Atomicity** — writes go to ``step_<N>.tmp`` and are renamed only after
  fsync; a crash mid-write never corrupts the latest checkpoint.
* **Async** — ``save_async`` snapshots to host RAM (device_get) and writes in
  a background thread; training continues.
* **Mesh elasticity** — leaves are stored as *full logical arrays*; restore
  re-shards onto whatever mesh/sharding the caller provides, so a 512-chip
  checkpoint restores on 256 chips or on this CPU (DESIGN.md §4).
* **Auto-resume** — ``latest_step`` scans for the newest manifest with
  ``done: true``; partial checkpoints are ignored and garbage-collected.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(p) for p in path)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _ = _flatten_with_paths(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **host)
    manifest = {
        "step": step,
        "keys": sorted(host.keys()),
        "shapes": {k: list(v.shape) for k, v in host.items()},
        "dtypes": {k: str(v.dtype) for k, v in host.items()},
        "extra": extra or {},
        "done": True,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Snapshot-on-call, write-in-background; ``wait()`` joins the writer."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save_async(self, step: int, tree, extra: dict | None = None) -> None:
        self.wait()
        host_tree = jax.device_get(tree)  # snapshot before training mutates

        def _write():
            save(self.ckpt_dir, step, host_tree, extra)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = all_steps(self.ckpt_dir)
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        mf = os.path.join(ckpt_dir, name, "manifest.json")
        try:
            with open(mf) as f:
                if json.load(f).get("done"):
                    out.append(int(name[5:]))
        except (OSError, ValueError):
            continue
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``; optionally device_put
    each leaf with the given sharding tree (mesh-elastic restore)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(final, "arrays.npz"))
    flat, treedef = _flatten_with_paths(like_tree)
    restored = {}
    for k, ref in flat.items():
        arr = data[k]
        assert tuple(arr.shape) == tuple(ref.shape), (k, arr.shape, ref.shape)
        restored[k] = arr
    leaves = [restored[k] for k in flat.keys()]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest
