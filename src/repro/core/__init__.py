"""KAN-SAs core: the paper's contribution as composable JAX modules.

* :mod:`repro.core.bspline`      -- exact + tabulated B-spline evaluation
* :mod:`repro.core.kan_layer`    -- KAN layers as GEMM workloads (all paths)
* :mod:`repro.core.quantization` -- integer-only inference (paper SecV)
* :mod:`repro.core.sa_model`     -- calibrated analytical SA model (Tab I/Figs 7-8)
* :mod:`repro.core.grid`         -- grid refinement + least-squares refit
"""

from repro.core.bspline import SplineGrid  # noqa: F401
