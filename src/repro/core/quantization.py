"""Integer-only KAN inference (paper §V, ref [18] Jacob et al.).

The paper's accelerator is int8-in / int32-accumulate: activations are
affine-quantised over the *extended grid domain* (so the Align/Compare units
can run the Eq. 5 integer address arithmetic), LUT values are uint8 with a
power-of-two dequantisation scale (Fig. 5 stores ``B·192``; we default to the
largest power of two that fits, e.g. ``B·256`` for cubic where
``max B_{0,3} = 2/3``), and spline coefficients are symmetric int8.

Validated claim (paper §V): "<1% accuracy drop for all the models
(e.g., MNIST-KAN drops from 96.58% to 96.0%)" — see
``benchmarks/quant_accuracy.py`` and ``examples/mnist_kan.py``.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bspline
from repro.core.bspline import SplineGrid


# ---------------------------------------------------------------------------
# Basic affine / symmetric quantisation helpers.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AffineQuant:
    """q = clip(round(x/scale) + zero, 0, 2^bits - 1)."""

    scale: float
    zero: int
    bits: int = 8

    @property
    def qmax(self) -> int:
        return (1 << self.bits) - 1

    def quantize(self, x: jax.Array) -> jax.Array:
        q = jnp.round(x / self.scale) + self.zero
        return jnp.clip(q, 0, self.qmax).astype(jnp.int32)

    def dequantize(self, q: jax.Array) -> jax.Array:
        return (q.astype(jnp.float32) - self.zero) * self.scale


def affine_from_range(lo: float, hi: float, bits: int = 8) -> AffineQuant:
    scale = (hi - lo) / ((1 << bits) - 1)
    zero = int(round(-lo / scale))
    return AffineQuant(scale=scale, zero=zero, bits=bits)


def symmetric_scales(w: jax.Array, axis=None, bits: int = 8) -> jax.Array:
    """Per-axis symmetric int8 scales: q = round(w/s), s = max|w|/127."""
    amax = jnp.max(jnp.abs(w), axis=axis, keepdims=axis is not None)
    return jnp.maximum(amax, 1e-12) / ((1 << (bits - 1)) - 1)


# ---------------------------------------------------------------------------
# Quantised LUT (paper Fig. 5) and integer address arithmetic (paper Eq. 5).
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=8)
def lut_value_scale(P: int) -> int:
    """Largest power-of-two s with max(B_{0,P}) * s <= 255 (uint8 values).

    For P=3: max = 2/3 -> s = 256 (the paper uses 192 = 3·2^6, which also
    preserves partition-of-unity in integers; both are supported — 256 keeps
    the dequant a pure shift)."""
    mx = float(bspline.cardinal_bspline(jnp.asarray((P + 1) / 2.0), P))
    return 1 << int(math.floor(math.log2(255.0 / mx)))


def build_lut_u8(P: int, S: int = 256, scale: int | None = None) -> np.ndarray:
    """uint8 half-table: round(B_{0,P} · scale) (paper Fig. 5 stores 8-bit
    values, two per row for P=3; generic: ceil((P+1)/2) per row)."""
    if scale is None:
        scale = lut_value_scale(P)
    tab = bspline.build_lut(P, S, dtype=np.float64) * scale
    return np.clip(np.round(tab), 0, 255).astype(np.uint8)


@dataclasses.dataclass(frozen=True)
class QuantizedGrid:
    """Integer-domain grid: activation quantisation aligned to the extended
    knot span so Eq. 5 address math is exact in int32."""

    grid: SplineGrid
    x_quant: AffineQuant
    lut_scale: int
    S: int = 256

    @staticmethod
    def make(grid: SplineGrid, S: int = 256, bits: int = 8) -> "QuantizedGrid":
        # Activations quantised over the *extended* domain [t0, t_last]
        # (paper §III-B2: x_q and t_q share one affine scheme).
        xq = affine_from_range(grid.t0, grid.t_last, bits)
        return QuantizedGrid(grid, xq, lut_value_scale(grid.P), S)


def int_address(qg: QuantizedGrid, x_q: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Integer Align+Compare (paper Eq. 5).

    ``u = (G+2P)·(x_q - t_q0)`` spans ``[0, (G+2P)·qmax]``; the interval index
    is ``k = u // qmax`` (Compare unit's interval search) and the LUT address
    is ``clip(u - qmax·k, 0, qmax)`` — exactly Eq. 5 with qmax = 255.
    """
    g = qg.grid
    qmax = qg.x_quant.qmax
    t_q0 = 0  # t0 quantises to the range minimum by construction
    u = (g.G + 2 * g.P) * (x_q - t_q0)                      # int32
    k = jnp.clip(u // qmax, g.P, g.n_basis - 1)
    addr = jnp.clip(u - qmax * k, 0, qmax)
    # Rescale the qmax-wide in-interval offset onto the S-entry table.
    addr = (addr * (qg.S - 1)) // qmax
    return addr.astype(jnp.int32), k.astype(jnp.int32)


def lut_fetch_u8(
    qg: QuantizedGrid, lut_u8: jax.Array, addr: jax.Array
) -> jax.Array:
    """Fetch the P+1 non-zero uint8 B-spline values (ascending basis index)
    using the direct + inverted-address scheme (paper Fig. 5's ``~`` unit)."""
    P = qg.grid.P
    half = lut_u8.shape[1]
    addr_inv = (qg.S - 1) - addr
    cols = []
    for i in range(P + 1):
        j = P - i
        if j < half:
            cols.append(lut_u8[addr, j])
        else:
            cols.append(lut_u8[addr_inv, P - j])
    return jnp.stack(cols, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Fully-quantised KAN layer forward (int8 x, uint8 LUT, int8 coeff, int32 acc).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QuantizedKANLayer:
    coeff_q: jax.Array      # (K, M, N) int8 as int32
    coeff_scale: jax.Array  # (1, 1, N) per-output-channel
    base_w_q: jax.Array | None
    base_w_scale: jax.Array | None
    qg: QuantizedGrid
    lut_u8: jax.Array


def quantize_kan_layer(params, grid: SplineGrid, S: int = 256) -> QuantizedKANLayer:
    qg = QuantizedGrid.make(grid, S)
    coeff = params["coeff"]
    cs = symmetric_scales(coeff, axis=(0, 1))
    coeff_q = jnp.clip(jnp.round(coeff / cs), -127, 127).astype(jnp.int32)
    base_w = params.get("base_w")
    if base_w is not None:
        bs_ = symmetric_scales(base_w, axis=0)
        base_q = jnp.clip(jnp.round(base_w / bs_), -127, 127).astype(jnp.int32)
    else:
        bs_, base_q = None, None
    return QuantizedKANLayer(
        coeff_q=coeff_q,
        coeff_scale=cs,
        base_w_q=base_q,
        base_w_scale=bs_,
        qg=qg,
        lut_u8=jnp.asarray(build_lut_u8(grid.P, S)),
    )


def _quantized_base_term(
    qlayer: QuantizedKANLayer, x_q: jax.Array, out_shape
) -> jax.Array | None:
    """Integer base term: ReLU in the quantised domain + int8 GEMM + rescale
    (paper Eq. 1 base term with ReLU instead of SiLU)."""
    if qlayer.base_w_q is None:
        return None
    qg = qlayer.qg
    relu_q = jnp.maximum(x_q, qg.x_quant.zero) - qg.x_quant.zero
    yb = jnp.einsum(
        "...k,kn->...n", relu_q, qlayer.base_w_q,
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    return (yb * (qlayer.base_w_scale.reshape(1, -1) * qg.x_quant.scale)).reshape(
        out_shape
    )


def quantized_kan_forward_fused(
    qlayer: QuantizedKANLayer, x: jax.Array
) -> jax.Array:
    """Kernel-backed integer forward: Align/Compare, ROM, band scatter, int8
    GEMM *and* the per-channel dequant all inside one ``pallas_call``
    (``repro.kernels.kan_int8_gemm``); emits ``x.dtype`` directly.

    Numerically identical to :func:`quantized_kan_forward` (same integer
    accumulator, same dequant multiply) — the serving path on TPU.
    """
    from repro.kernels import ops as kops

    qg = qlayer.qg
    x_q = qg.x_quant.quantize(x)                       # (..., K) int32
    scale = qlayer.coeff_scale.reshape(-1) / qg.lut_scale
    y = kops.kan_int8_gemm(
        x_q, qlayer.lut_u8, qlayer.coeff_q.astype(jnp.int8), qg.grid,
        scale=scale, lut_scale=qg.lut_scale, out_dtype=x.dtype,
    )
    base = _quantized_base_term(qlayer, x_q, y.shape)
    return y if base is None else y + base.astype(y.dtype)


def quantized_kan_forward(qlayer: QuantizedKANLayer, x: jax.Array) -> jax.Array:
    """End-to-end integer KAN layer (paper §V 'integer-only implementation').

    Returns float32 output (the accumulator is int32; the final rescale is
    the only float op, as in [18])."""
    qg = qlayer.qg
    g = qg.grid
    P = g.P
    x_q = qg.x_quant.quantize(x)                       # (..., K) int32
    addr, k = int_address(qg, x_q)
    bvals = lut_fetch_u8(qg, qlayer.lut_u8, addr)      # (..., K, P+1) int32
    # Gather int8 coefficient slabs (the M-to-N multiplexer) and accumulate
    # in int32: psum += sum_i c_{k-P+i} · B_i  (paper §IV-A).
    K, M, N = qlayer.coeff_q.shape
    m_idx = k[..., None] - P + jnp.arange(P + 1, dtype=k.dtype)
    flat_m = m_idx.reshape(-1, K, P + 1)
    coeff_b = jnp.broadcast_to(qlayer.coeff_q, flat_m.shape[:1] + qlayer.coeff_q.shape)
    slabs = jnp.take_along_axis(coeff_b, flat_m[..., None], axis=2, mode="clip")
    acc = jnp.einsum(
        "bki,bkin->bn",
        bvals.reshape(-1, K, P + 1),
        slabs,
        preferred_element_type=jnp.int32,
    )
    y = acc.astype(jnp.float32).reshape(x.shape[:-1] + (N,))
    y = y * (qlayer.coeff_scale.reshape(1, -1) / qg.lut_scale)
    base = _quantized_base_term(qlayer, x_q, y.shape)
    return y if base is None else y + base
