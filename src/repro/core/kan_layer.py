"""KAN layers as GEMM workloads (paper §II-A, Eq. 1, Fig. 1c).

``KANLayer(x) = sum_j phi_j(x_j) + w_b · b(x)`` with ``phi`` parameterised in
the B-spline basis: ``phi(x) = sum_m c_m B_m(x)``. The ``w_i`` scales of
Eq. 1 are absorbed into the coefficients (paper §II-A: "at inference time,
they can be absorbed in the functions"); the base nonlinearity ``b`` is ReLU
(paper: "It is typically a SiLU but we replace it with a ReLU").

Forward paths (selectable, all numerically cross-checked in tests):

* ``dense``   — materialise the full ``B : (BS, K, G+P)`` activation tensor via
  exact Cox-de Boor and contract with XLA. This is the *conventional SA*
  baseline of the paper (the scalar-PE array chewing through zeros) and the
  differentiable training path.
* ``compact`` — the N:M form: only the ``P+1`` non-zero values are produced and
  the matching coefficient slabs are *gathered* per input (the paper's
  M-to-N multiplexer). Wins on TPU in the small-batch/decode regime.
* ``lut``     — tabulated evaluation (paper Fig. 5) scattered dense; inference.
* ``fused``   — Pallas kernel: B tile built on the fly in VMEM, MXU contraction
  (the paper's B-spline unit streaming straight into the systolic array).
  Spline AND base term execute in a single ``pallas_call`` (the base GEMM is
  a kernel epilogue on the already-resident x tile).  Requires
  ``repro.kernels``; CPU tests run it with ``interpret=True``.
* ``sparse``  — Pallas kernel: the paper's N:M vector PE (§IV-A/B). Each input
  contracts only its ``P+1`` non-zero values against a *gathered*
  ``(P+1, N)`` coefficient slab — ``(G+P)/(P+1)×`` fewer MACs and
  coefficient reads than ``fused``; wins in the memory-bound small-batch /
  decode regime (DESIGN.md §2a).
* ``auto``    — :func:`resolve_inference_method`: on TPU, ``sparse`` at decode
  row counts and ``fused`` otherwise; ``compact`` off-TPU (interpret-mode
  Pallas is correct but slow on CPU).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bspline
from repro.core.bspline import SplineGrid

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class KANLayerConfig:
    in_dim: int
    out_dim: int
    grid: SplineGrid = SplineGrid()
    base: bool = True           # include the w_b · ReLU(x) term of Eq. 1
    noise_scale: float = 0.1    # init scale for spline coefficients
    lut_size: int = 256         # paper: 8-bit address -> 256 entries


def init_kan_layer(key: jax.Array, cfg: KANLayerConfig, dtype=jnp.float32) -> Params:
    """Coefficients ``(K, M, N)`` + base weight ``(K, N)``."""
    k_coef, k_base = jax.random.split(key)
    M = cfg.grid.n_basis
    coeff = cfg.noise_scale * jax.random.normal(
        k_coef, (cfg.in_dim, M, cfg.out_dim), dtype
    ) / math.sqrt(cfg.in_dim * (cfg.grid.P + 1))
    params: Params = {"coeff": coeff}
    if cfg.base:
        params["base_w"] = jax.random.normal(
            k_base, (cfg.in_dim, cfg.out_dim), dtype
        ) * math.sqrt(2.0 / cfg.in_dim)
    return params


def _base_term(params: Params, x: jax.Array) -> jax.Array:
    if "base_w" not in params:
        return jnp.zeros(x.shape[:-1] + (params["coeff"].shape[-1],), x.dtype)
    return jax.nn.relu(x) @ params["base_w"]


def kan_layer_dense(params: Params, x: jax.Array, grid: SplineGrid) -> jax.Array:
    """Conventional-SA baseline: dense B materialisation + GEMM (Fig. 1c)."""
    B = bspline.cox_de_boor_dense(x, grid)            # (..., K, M)
    y = jnp.einsum("...km,kmn->...n", B, params["coeff"])
    return y + _base_term(params, x)


def kan_layer_compact(params: Params, x: jax.Array, grid: SplineGrid) -> jax.Array:
    """N:M sparsity-aware path (paper §IV): compute only the P+1 non-zero
    values and gather their coefficients — no multiplications with zero.

    The coefficient-slab gather ``C[j, k-P+i, :]`` is the software analogue of
    the paper's M-to-N multiplexer (select-by-``k``). It moves
    ``BS·K·(P+1)·N`` coefficient elements, so on TPU it wins over the dense
    panel (``K·M·N``) exactly in the small-batch/decode regime — see DESIGN.md.
    """
    vals, k = bspline.compact_basis(x, grid)          # (..., K, P+1), (..., K)
    coeff = params["coeff"]                           # (K, M, N)
    K = coeff.shape[0]
    m_idx = k[..., None] - grid.P + jnp.arange(grid.P + 1, dtype=k.dtype)
    flat_m = m_idx.reshape(-1, K, grid.P + 1)         # (BSf, K, P+1)
    coeff_b = jnp.broadcast_to(coeff, flat_m.shape[:1] + coeff.shape)
    slabs = jnp.take_along_axis(                      # (BSf, K, P+1, N)
        coeff_b, flat_m[..., None].astype(jnp.int32), axis=2, mode="clip"
    )
    vals_f = vals.reshape(-1, K, grid.P + 1)
    y = jnp.einsum("bki,bkin->bn", vals_f, slabs)
    y = y.reshape(x.shape[:-1] + (coeff.shape[-1],))
    return y + _base_term(params, x)


def kan_layer_lut(
    params: Params, x: jax.Array, grid: SplineGrid, lut: jax.Array
) -> jax.Array:
    """Tabulated inference path (paper Fig. 5) — dense scatter + GEMM."""
    B = bspline.lut_basis_dense(x, grid, lut)
    y = jnp.einsum("...km,kmn->...n", B, params["coeff"])
    return y + _base_term(params, x)


@functools.lru_cache(maxsize=4)
def _sparse_kernel_compiles(backend: str) -> bool:
    """Probe (once per process) that the deployed compiler can lower the
    sparse kernel's VMEM gather (Mosaic dynamic-gather) — so ``auto`` can
    fall back to the proven fused kernel instead of failing every decode
    step on a jaxlib without it.  Only probes when the queried backend is
    the *actual* one (hypothetical queries, e.g. a CPU-hosted dry-run asking
    about TPU, assume support)."""
    if backend != "tpu" or jax.default_backend() != "tpu":
        return True  # off-TPU runs interpret mode: plain XLA gather
    try:
        from repro.kernels import ops as kops

        g = SplineGrid()
        x = jnp.zeros((1, 2), jnp.float32)
        c = jnp.zeros((2, g.n_basis, 8), jnp.float32)
        jax.block_until_ready(
            kops.kan_sparse_gemm(x, c, g, bb=8, bn=8, bk=2, interpret=False)
        )
        return True
    except Exception:
        return False


def resolve_inference_method(
    backend: str | None = None, rows: int | None = None
) -> str:
    """The default serving path per backend and batch regime (DESIGN.md §2a).

    On TPU: the ``sparse`` N:M kernel when the flattened row count is in the
    decode/small-batch regime (``rows <= $KAN_SAS_SPARSE_MAX_ROWS``,
    default 8) — there the dense-band GEMM is memory-bound and the sparse
    kernel's ``(G+P)/(P+1)×`` smaller coefficient stream wins; the ``fused``
    kernel otherwise (one kernel per layer, B never in HBM — DESIGN.md §2).
    Off-TPU: ``compact`` (interpret-mode Pallas is correct on CPU but orders
    of magnitude slower than the XLA gather path).

    ``rows`` is the number of flattened input rows the layer will see
    (batch·seq for prefill, batch for decode); when unknown (``None``) the
    large-batch answer is returned.  ``$KAN_SAS_INFERENCE_METHOD`` overrides
    everything — e.g. a CPU-hosted dry-run lowering the program it will
    actually serve on TPU sets it to ``fused``, and a TPU debug session can
    force ``compact``.
    """
    import os

    forced = os.environ.get("KAN_SAS_INFERENCE_METHOD")
    if forced:
        return forced
    backend = backend or jax.default_backend()
    if backend != "tpu":
        return "compact"
    max_rows = int(os.environ.get("KAN_SAS_SPARSE_MAX_ROWS", "8"))
    if rows is not None and rows <= max_rows and _sparse_kernel_compiles(backend):
        return "sparse"
    return "fused"


def kan_layer_apply(
    params: Params,
    x: jax.Array,
    grid: SplineGrid,
    method: str = "dense",
    lut: jax.Array | None = None,
) -> jax.Array:
    if method == "auto":
        # rows = flattened inputs the kernel will see: the batch-regime
        # signal that picks sparse (decode) vs fused (prefill/train) on TPU.
        method = resolve_inference_method(rows=math.prod(x.shape[:-1]))
    if method == "dense":
        return kan_layer_dense(params, x, grid)
    if method == "compact":
        return kan_layer_compact(params, x, grid)
    if method == "lut":
        if lut is None:
            lut = jnp.asarray(bspline.build_lut(grid.P))
        return kan_layer_lut(params, x, grid, lut)
    if method == "fused":
        from repro.kernels import ops as kops

        # Spline + base in ONE pallas_call: the base term is an epilogue
        # contraction on the x tile already resident in VMEM.
        return kops.kan_fused_gemm(
            x, params["coeff"], grid, base_w=params.get("base_w")
        )
    if method == "sparse":
        from repro.kernels import ops as kops

        # The N:M vector PE: P+1-wide gathered-slab contraction, base term
        # fused as the same epilogue — one pallas_call per layer.
        return kops.kan_sparse_gemm(
            x, params["coeff"], grid, base_w=params.get("base_w")
        )
    raise ValueError(f"unknown method {method!r}")


# ---------------------------------------------------------------------------
# KAN stacks (MLP-style) and ConvKAN — the paper's application workloads.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KANNetConfig:
    """A KAN MLP: e.g. MNIST-KAN is ``layers=[784, 64, 10], G=10, P=3``."""

    layers: tuple[int, ...]
    G: int = 5
    P: int = 3
    x_min: float = -1.0
    x_max: float = 1.0
    base: bool = True
    layer_norm: bool = True  # keep activations in-domain between layers

    def grid(self) -> SplineGrid:
        return SplineGrid(self.x_min, self.x_max, self.G, self.P)


def init_kan_net(key: jax.Array, cfg: KANNetConfig, dtype=jnp.float32) -> list[Params]:
    keys = jax.random.split(key, len(cfg.layers) - 1)
    return [
        init_kan_layer(
            k,
            KANLayerConfig(cfg.layers[i], cfg.layers[i + 1], cfg.grid(), base=cfg.base),
            dtype,
        )
        for i, k in enumerate(keys)
    ]


def _tanh_norm(h: jax.Array) -> jax.Array:
    """Map intermediate activations back into the spline domain.

    KAN reference impls keep activations in the grid range either by grid
    updates (training-time) or normalisation; we use a smooth tanh squash,
    which keeps the LUT/int8 paths' clipping honest.
    """
    return jnp.tanh(h)


def kan_net_apply(
    params: list[Params],
    x: jax.Array,
    cfg: KANNetConfig,
    method: str = "dense",
    lut: jax.Array | None = None,
) -> jax.Array:
    g = cfg.grid()
    h = x
    for i, p in enumerate(params):
        if i > 0 and cfg.layer_norm:
            h = _tanh_norm(h)
        h = kan_layer_apply(p, h, g, method=method, lut=lut)
    return h


# ---------------------------------------------------------------------------
# ConvKAN (ResKAN18 building block): scalar conv filter weights replaced by
# splines; realised as im2col + KANLayer (paper §V-C, refs [16],[29],[32]).
# ---------------------------------------------------------------------------


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1, pad: int = 0) -> jax.Array:
    """(B, H, W, C) -> (B, Ho, Wo, kh*kw*C) patches."""
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    B, H, W, C = x.shape
    Ho = (H - kh) // stride + 1
    Wo = (W - kw) // stride + 1
    patches = jax.lax.conv_general_dilated_patches(
        x.transpose(0, 3, 1, 2), (kh, kw), (stride, stride), "VALID"
    )  # (B, C*kh*kw, Ho, Wo)
    return patches.transpose(0, 2, 3, 1).reshape(B, Ho, Wo, C * kh * kw)


def conv_kan_apply(
    params: Params,
    x: jax.Array,
    grid: SplineGrid,
    kh: int = 3,
    kw: int = 3,
    stride: int = 1,
    pad: int = 1,
    method: str = "dense",
) -> jax.Array:
    """ConvKAN layer: each filter tap is a learnable spline."""
    patches = im2col(x, kh, kw, stride, pad)       # (B, Ho, Wo, kh*kw*C)
    B, Ho, Wo, Kin = patches.shape
    y = kan_layer_apply(params, patches.reshape(-1, Kin), grid, method=method)
    return y.reshape(B, Ho, Wo, -1)


def kan_layer_flops(BS: int, K: int, N: int, grid: SplineGrid) -> dict[str, float]:
    """Useful vs dense FLOP accounting (paper §IV-A utilisation argument)."""
    M, Nnz = grid.n_basis, grid.n_nonzero
    return {
        "dense_macs": float(BS * K * M * N),
        "useful_macs": float(BS * K * Nnz * N),
        "density": Nnz / M,
    }
