"""B-spline math for KAN layers (paper §II-A, §III-B).

Implements, in pure JAX:

* the exact (differentiable) Cox-de Boor evaluation (paper Eq. 2-3) — the
  software oracle and the training path;
* the *cardinal* B-spline reduction on uniform grids (paper Eq. 4):
  ``B_{t_k,P}(x) = B_{0,P}((x - t0)/delta - k)``;
* the compact N:M form exploiting local support (paper §IV-A): for any input
  only ``N = P+1`` contiguous basis functions out of ``M = G+P`` are non-zero;
* the tabulation strategy (paper §III-B, Fig. 4-5): half-table storage using
  the symmetry ``B_{0,P}(t) = B_{0,P}(P+1-t)`` and the inverted-address fetch.

Conventions
-----------
A uniform grid with ``G`` intervals over ``[x_min, x_max]`` and degree ``P``
is extended by ``P`` intervals on each side (paper Fig. 2):

* knots ``t_i = x_min + (i - P) * delta`` for ``i = 0 .. G+2P``
  (``G+2P+1`` knots, ``delta = (x_max-x_min)/G``);
* ``N_b = G+P`` basis functions ``B_0 .. B_{G+P-1}``; ``B_m`` is supported on
  ``[t_m, t_{m+P+1})``;
* an in-domain input lies in interval ``k`` with ``t_k <= x < t_{k+1}``,
  ``k in [P, G+P-1]``, and its non-zero functions are ``B_{k-P} .. B_k``.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SplineGrid",
    "cox_de_boor_dense",
    "cardinal_bspline",
    "align",
    "interval_index",
    "compact_basis",
    "compact_to_dense",
    "build_lut",
    "lut_basis_compact",
    "lut_basis_dense",
]


@dataclasses.dataclass(frozen=True)
class SplineGrid:
    """A uniform, extended B-spline grid (paper Fig. 2)."""

    x_min: float = -1.0
    x_max: float = 1.0
    G: int = 5
    P: int = 3

    def __post_init__(self):
        if self.G < 1 or self.P < 1:
            raise ValueError(f"G >= 1 and P >= 1 required, got G={self.G} P={self.P}")
        if not self.x_max > self.x_min:
            raise ValueError("x_max must exceed x_min")

    @property
    def delta(self) -> float:
        return (self.x_max - self.x_min) / self.G

    @property
    def n_basis(self) -> int:
        """M = G+P basis functions (paper §II-A)."""
        return self.G + self.P

    @property
    def n_nonzero(self) -> int:
        """N = P+1 non-zero basis values per input (paper §IV-A)."""
        return self.P + 1

    @property
    def t0(self) -> float:
        """First extended knot, t_0 = x_min - P*delta."""
        return self.x_min - self.P * self.delta

    @property
    def t_last(self) -> float:
        """Last extended knot, t_{G+2P}."""
        return self.x_min + (self.G + self.P) * self.delta

    def knots(self) -> np.ndarray:
        """All G+2P+1 extended knots."""
        return self.t0 + self.delta * np.arange(self.G + 2 * self.P + 1)

    def half_cols(self) -> int:
        """Columns of the half-table: ceil((P+1)/2) unit intervals cover half
        the cardinal support [0, P+1] (paper §III-B: 'we only need to store
        half the B-spline')."""
        return math.ceil((self.P + 1) / 2)


# ---------------------------------------------------------------------------
# Exact evaluation (Cox-de Boor, paper Eq. 2-3) — differentiable oracle.
# ---------------------------------------------------------------------------


def cox_de_boor_dense(x: jax.Array, grid: SplineGrid) -> jax.Array:
    """All ``G+P`` basis values at ``x``: output shape ``x.shape + (G+P,)``.

    Iterative (bottom-up) Cox-de Boor; differentiable in ``x`` a.e. and exact
    for any degree. This is the paper's "conventional" software evaluation and
    the oracle for the tabulated paths.

    Boundary convention (shared by every evaluation path): out-of-domain
    inputs saturate to the boundary basis (the paper's Eq. 5 address clip),
    and ``x == x_max`` activates the *last in-domain* interval — the basis at
    the right edge is ``B_G .. B_{G+P-1}`` evaluated as the left limit, never
    the all-zero row a purely half-open interval test would produce.
    """
    knots = jnp.asarray(grid.knots(), dtype=x.dtype)
    # Saturate out-of-domain inputs to the boundary (Eq. 5 address clip, as
    # the compact/LUT/kernel paths do). Clamping to the *knot values* makes
    # the endpoint tests below exact in x.dtype.
    xx = jnp.clip(x, knots[grid.P], knots[grid.n_basis])[..., None]
    # Degree 0: indicator of each of the G+2P intervals.
    inside = (xx >= knots[:-1]) & (xx < knots[1:])
    # Close the right edge of the last in-domain interval: x == x_max belongs
    # to [t_{G+P-1}, t_{G+P}] (left limit), not to the first right-extension
    # interval — with half-open tests alone the endpoint basis would depend
    # on extension intervals existing (and is all-zero for clamped knots).
    iota = jnp.arange(knots.shape[0] - 1)
    on_edge = xx == knots[grid.n_basis]
    inside = (inside | (on_edge & (iota == grid.n_basis - 1))) & ~(
        on_edge & (iota == grid.n_basis)
    )
    b = jnp.where(inside, 1.0, 0.0).astype(x.dtype)
    for p in range(1, grid.P + 1):
        t_i = knots[: -(p + 1)]          # t_i
        t_ip = knots[p:-1]               # t_{i+p}
        t_i1 = knots[1:-p]               # t_{i+1}
        t_ip1 = knots[p + 1:]            # t_{i+p+1}
        left = (xx - t_i) / (t_ip - t_i) * b[..., :-1]
        right = (t_ip1 - xx) / (t_ip1 - t_i1) * b[..., 1:]
        b = left + right
    return b[..., : grid.n_basis]


@functools.partial(jax.jit, static_argnames=("P",))
def cardinal_bspline(u: jax.Array, P: int) -> jax.Array:
    """Cardinal B-spline ``B_{0,P}(u)`` on integer knots ``0..P+1``.

    Support is ``[0, P+1)``; symmetric about ``(P+1)/2`` (paper §III-B).
    """
    u = jnp.asarray(u)
    uu = u[..., None]
    i = jnp.arange(P + 2, dtype=u.dtype)
    b = jnp.where((uu >= i[:-1]) & (uu < i[1:]), 1.0, 0.0).astype(u.dtype)
    for p in range(1, P + 1):
        # Integer knots: t_{i+p} - t_i = p, t_{i+p+1} - t_{i+1} = p.
        idx = jnp.arange(P + 1 - p, dtype=u.dtype)
        left = (uu - idx) / p * b[..., :-1]
        right = (idx + p + 1 - uu) / p * b[..., 1:]
        b = left + right
    return b[..., 0]


# ---------------------------------------------------------------------------
# Alignment + compact N:M form (paper Eq. 4, §IV-A).
# ---------------------------------------------------------------------------


def align(x: jax.Array, grid: SplineGrid) -> jax.Array:
    """Aligned coordinate ``z = (x - t0)/delta`` (paper Eq. 4, the Align unit)."""
    return (x - grid.t0) / jnp.asarray(grid.delta, dtype=x.dtype)


def interval_index(x: jax.Array, grid: SplineGrid) -> jax.Array:
    """Interval index ``k`` with ``t_k <= x < t_{k+1}`` (the Compare unit).

    Clipped to the valid in-domain range ``[P, G+P-1]``; out-of-domain inputs
    saturate to the boundary interval (the paper's address clip, Eq. 5).
    """
    z = align(x, grid)
    k = jnp.floor(z).astype(jnp.int32)
    return jnp.clip(k, grid.P, grid.n_basis - 1)


def compact_basis(x: jax.Array, grid: SplineGrid) -> tuple[jax.Array, jax.Array]:
    """Exact compact N:M evaluation.

    Returns ``(vals, k)`` where ``vals.shape = x.shape + (P+1,)`` holds the
    values of the non-zero functions ``B_{k-P} .. B_k`` (ascending index) and
    ``k`` is the interval index. ``vals[..., i] = B_{0,P}(x_a + P - i)`` with
    ``x_a = z - k`` the in-interval offset (paper Fig. 4).
    """
    z = align(x, grid)
    k = interval_index(x, grid)
    # Saturate the in-interval offset (paper Eq. 5 address clip): out-of-
    # domain inputs evaluate the boundary basis, matching the dense oracle,
    # the LUT path and the Pallas kernels (compact_basis_inblock).
    xa = jnp.clip(z - k.astype(z.dtype), 0.0, 1.0)
    offs = jnp.arange(grid.P, -1, -1, dtype=z.dtype)  # P, P-1, ..., 0
    vals = cardinal_bspline(xa[..., None] + offs, grid.P)
    return vals, k


def compact_to_dense(vals: jax.Array, k: jax.Array, grid: SplineGrid) -> jax.Array:
    """Scatter compact values into the dense ``(..., G+P)`` layout.

    This is the TPU analogue of the paper's M-to-N multiplexer run in reverse:
    a compare-against-iota one-hot select, which keeps everything vectorised.
    """
    m = jnp.arange(grid.n_basis, dtype=jnp.int32)
    # dense[..., m] = vals[..., m - (k-P)] where 0 <= m-(k-P) <= P.
    rel = m - (k[..., None] - grid.P)
    inside = (rel >= 0) & (rel <= grid.P)
    gathered = jnp.take_along_axis(
        vals, jnp.clip(rel, 0, grid.P), axis=-1, mode="clip"
    )
    return jnp.where(inside, gathered, 0.0).astype(vals.dtype)


# ---------------------------------------------------------------------------
# Tabulation (paper §III-B, Fig. 4-5).
# ---------------------------------------------------------------------------


def build_lut(P: int, S: int = 256, dtype=np.float32) -> np.ndarray:
    """Build the half-table of the cardinal B-spline.

    ``T[a, c] = B_{0,P}(a/(S-1) + c)`` for ``a in [0, S)`` and
    ``c in [0, ceil((P+1)/2))``. Together with the inverted-address fetch this
    covers the full support ``[0, P+1]`` (paper Fig. 4: only ``[0, (P+1)/2]``
    is stored; Fig. 5: two values per row for P=3).
    """
    cols = math.ceil((P + 1) / 2)
    a = np.arange(S, dtype=np.float64) / (S - 1)
    u = a[:, None] + np.arange(cols)[None, :]
    tab = np.asarray(cardinal_bspline(jnp.asarray(u), P))
    return tab.astype(dtype)


def lut_basis_compact(
    x: jax.Array, grid: SplineGrid, lut: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Tabulated compact evaluation (paper Fig. 5).

    For in-interval offset ``x_a`` quantised to address ``addr`` in
    ``[0, S-1]``, the needed values are ``B0(x_a + j)`` for ``j = 0..P``:

    * ``j <  ceil((P+1)/2)``: direct fetch ``T[addr, j]``;
    * ``j >= ceil((P+1)/2)``: symmetry ``B0(x_a+j) = B0((1-x_a) + (P-j))`` —
      fetch ``T[S-1-addr, P-j]`` (the paper's ``~`` inversion unit, with the
      values "reverse-packed").

    Output ``vals[..., i]`` is ordered by ascending basis index (``j = P-i``),
    matching :func:`compact_basis`.
    """
    S = lut.shape[0]
    half = lut.shape[1]
    P = grid.P
    z = align(x, grid)
    k = interval_index(x, grid)
    xa = jnp.clip(z - k.astype(z.dtype), 0.0, 1.0)
    addr = jnp.clip(jnp.round(xa * (S - 1)).astype(jnp.int32), 0, S - 1)
    addr_inv = (S - 1) - addr
    cols = []
    for i in range(P + 1):  # ascending basis index m = k-P+i
        j = P - i
        if j < half:
            cols.append(lut[addr, j])
        else:
            cols.append(lut[addr_inv, P - j])
    vals = jnp.stack(cols, axis=-1)
    return vals, k


def lut_basis_dense(x: jax.Array, grid: SplineGrid, lut: jax.Array) -> jax.Array:
    """Tabulated evaluation scattered to the dense ``(..., G+P)`` layout."""
    vals, k = lut_basis_compact(x, grid, lut)
    return compact_to_dense(vals, k, grid)
