"""Grid utilities: uniform-grid refinement with least-squares refit.

Paper §II-B: "The only assumption we make is that of a uniform grid ...
as demonstrated by [1], it is possible to fine-grain the grid without
retraining, using least squares to compute the new coefficients. This
enables the approximation of non-uniform grids through finer uniform grids."

This module implements exactly that: given coefficients on a coarse (or
non-uniform) grid, fit coefficients on a finer uniform grid by sampling the
spline densely and solving the linear least-squares system in the new basis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bspline
from repro.core.bspline import SplineGrid


def refine_grid(grid: SplineGrid, factor: int = 2) -> SplineGrid:
    """A finer uniform grid over the same domain (G -> G*factor)."""
    return SplineGrid(grid.x_min, grid.x_max, grid.G * factor, grid.P)


def refit_coefficients(
    coeff: jax.Array,
    old_grid: SplineGrid,
    new_grid: SplineGrid,
    n_samples: int = 512,
) -> jax.Array:
    """Least-squares refit of KAN coefficients onto a new grid.

    coeff: (K, M_old, N) -> returns (K, M_new, N) minimising
    ``||B_new @ c_new - B_old @ c_old||`` over dense domain samples.

    The least-squares solve always runs in (at least) float32: under bf16
    coefficients ``jnp.linalg.lstsq`` is unsupported-or-garbage, so the
    system is promoted for the solve and the solution cast back.
    """
    solve_dtype = jnp.promote_types(coeff.dtype, jnp.float32)
    xs = jnp.linspace(old_grid.x_min, old_grid.x_max, n_samples, dtype=solve_dtype)
    B_old = bspline.cox_de_boor_dense(xs, old_grid)      # (S, M_old)
    B_new = bspline.cox_de_boor_dense(xs, new_grid)      # (S, M_new)
    targets = jnp.einsum("sm,kmn->skn", B_old, coeff.astype(solve_dtype))
    sol = jnp.linalg.lstsq(B_new, targets.reshape(n_samples, -1))[0]
    K, _, N = coeff.shape
    out = sol.reshape(new_grid.n_basis, K, N).transpose(1, 0, 2)
    return out.astype(coeff.dtype)


def nonuniform_to_uniform(
    knots: np.ndarray,
    coeff: jax.Array,
    P: int,
    G_new: int,
    n_samples: int = 1024,
) -> tuple[SplineGrid, jax.Array]:
    """Approximate a spline on a *non-uniform* knot sequence by a finer
    uniform grid (the paper's §II-B generality argument).

    knots: full extended non-uniform knot vector (len = G_old + 2P + 1);
    coeff: (K, G_old+P, N).
    """
    knots = np.asarray(knots, dtype=np.float64)
    x_min, x_max = float(knots[P]), float(knots[-P - 1])
    new_grid = SplineGrid(x_min, x_max, G_new, P)
    xs_np = np.linspace(x_min, x_max, n_samples)
    # Evaluate the non-uniform basis exactly (generic Cox-de Boor on the
    # provided knots) — small numpy loop is fine, this is an offline refit.
    M_old = len(knots) - P - 1
    b = np.where(
        (xs_np[:, None] >= knots[None, :-1]) & (xs_np[:, None] < knots[None, 1:]),
        1.0, 0.0,
    )
    # Close the right edge of the last in-domain interval. With half-open
    # tests alone the sample at exactly x_max lands in no interval when the
    # right knots are clamped/repeated (the usual non-uniform convention) —
    # the basis row is all-zero and the lstsq targets are corrupted, since
    # np.linspace includes the endpoint.
    dom = np.where((knots[:-1] < knots[1:]) & (knots[1:] <= x_max + 1e-12))[0]
    last_dom = int(dom.max())
    on_edge = xs_np >= knots[last_dom + 1]
    b[on_edge] = 0.0
    b[on_edge, last_dom] = 1.0
    for p in range(1, P + 1):
        nb = np.zeros((n_samples, b.shape[1] - 1))
        for i in range(b.shape[1] - 1):
            d1 = knots[i + p] - knots[i]
            d2 = knots[i + p + 1] - knots[i + 1]
            left = ((xs_np - knots[i]) / d1) * b[:, i] if d1 > 0 else 0.0
            right = ((knots[i + p + 1] - xs_np) / d2) * b[:, i + 1] if d2 > 0 else 0.0
            nb[:, i] = left + right
        b = nb
    solve_dtype = jnp.promote_types(coeff.dtype, jnp.float32)  # lstsq needs fp32+
    xs = jnp.asarray(xs_np, dtype=solve_dtype)
    B_old = jnp.asarray(b[:, :M_old], dtype=solve_dtype)
    B_new = bspline.cox_de_boor_dense(xs, new_grid)
    targets = jnp.einsum("sm,kmn->skn", B_old, coeff.astype(solve_dtype))
    sol = jnp.linalg.lstsq(B_new, targets.reshape(n_samples, -1))[0]
    K, _, N = coeff.shape
    out = sol.reshape(new_grid.n_basis, K, N).transpose(1, 0, 2)
    return new_grid, out.astype(coeff.dtype)
