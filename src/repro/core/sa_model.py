"""Analytical weight-stationary systolic-array model (paper §IV-V).

The paper evaluates KAN-SAs with Synopsys DC on 28nm FD-SOI; we cannot run
synthesis here, so this module provides a calibrated analytical model of the
two arrays whose constants are the paper's own published numbers:

* Table I      — post-synthesis delay (ns) and power (mW) per PE for sparsity
                 patterns 1:1, 1:2, 2:4, 2:6, 4:6, 4:8 (8-bit in, 32-bit acc,
                 500 MHz target);
* §V-B         — B-spline unit area = 450 um^2 (1-cycle tabulated lookup);
                 FPMax FP32 FMA = 0.0081 mm^2, latency 4 (ArKANe PE proxy);
* Fig 7/8      — calibration areas: 16x16 KAN-SAs (4:8) = 0.47 mm^2 and
                 32x32 scalar SA = 0.50 mm^2.

Model predictions are validated against every headline claim of the paper in
``benchmarks/`` (Table I normalized energy, the 30% / 99.25% MNIST-KAN
utilizations, the 39.9% average utilization gain, the ~50% cycle reduction,
and the 72x ArKANe comparison).

Cycle/utilization semantics (verified to reproduce Fig 8 exactly): a KAN
GEMM with input (BS, K), basis size M = G+P, N = P+1 non-zeros and output
width N_out maps onto an RxC weight-stationary array as

* conventional (scalar PE): the dense B matrix has K*M rows ->
  ``ceil(K*M/R) * ceil(N_out/C)`` tiles, BS streaming cycles per tile; every
  PE-cycle is a MAC slot but only the non-zero B values are useful ->
  utilization ~ N/M x tiling losses (paper §IV-A: "reduced to 30%").
* KAN-SAs (N:M vector PE): one vector row per input feature ->
  ``ceil(K/R) * ceil(N_out/C)`` tiles, each PE-cycle offers N useful lanes ->
  utilization ~ 100% x tiling losses; cycles drop by (G+P)x per row-pass
  (paper §V-A: "the 1:1 PE takes (G+P) times more cycles").
* MLP/base-term GEMMs (Eq. 1 second term, or any conventional DNN layer):
  scalar rows = K; the N:M PE packs N dense rows per vector row
  (paper §V-C: "(RxN, C) tiles of non-KAN workloads").
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

# --------------------------- paper constants -------------------------------

# Table I: (N, M) -> (delay ns, power mW)
TABLE_I = {
    (1, 1): (1.02, 0.35),
    (1, 2): (1.05, 0.40),
    (2, 4): (1.15, 0.62),
    (2, 6): (1.19, 0.77),
    (4, 6): (1.28, 0.98),
    (4, 8): (1.31, 1.12),
}
TABLE_I_NORM_ENERGY = {
    (1, 1): 1.00, (1, 2): 0.57, (2, 4): 0.44,
    (2, 6): 0.37, (4, 6): 0.47, (4, 8): 0.40,
}

BSPLINE_UNIT_AREA_UM2 = 450.0          # §V-B
FPMAX_FMA_AREA_MM2 = 0.0081            # §V-B (FPMax [24])
FPMAX_FMA_LATENCY = 4                  # §V-B
CAL_KANSAS_16x16_MM2 = 0.47            # Fig 8 caption
CAL_SCALAR_32x32_MM2 = 0.50            # Fig 8 caption
FREQ_HZ = 500e6

# Calibrated per-PE areas (um^2): array area = R*C*a_pe + R*a_bspline.
_A_SCALAR_UM2 = (CAL_SCALAR_32x32_MM2 * 1e6 - 32 * BSPLINE_UNIT_AREA_UM2) / (32 * 32)
_A_NM_48_UM2 = (CAL_KANSAS_16x16_MM2 * 1e6 - 16 * BSPLINE_UNIT_AREA_UM2) / (16 * 16)


def _fit_power() -> tuple[float, float, float]:
    """Least-squares p(N, M) = a + b*N + c*M over Table I."""
    pts = np.array([[1, n, m] for (n, m) in TABLE_I])
    pw = np.array([TABLE_I[k][1] for k in TABLE_I])
    coef, *_ = np.linalg.lstsq(pts.astype(float), pw, rcond=None)
    return tuple(coef)  # type: ignore[return-value]


_PW_COEF = _fit_power()


def pe_power_mw(N: int, M: int) -> float:
    """Table I power, exact where published, fitted elsewhere."""
    if (N, M) in TABLE_I:
        return TABLE_I[(N, M)][1]
    a, b, c = _PW_COEF
    return float(a + b * N + c * M)


def pe_delay_ns(N: int, M: int) -> float:
    if (N, M) in TABLE_I:
        return TABLE_I[(N, M)][0]
    # Adder tree depth grows with log N, mux with log M (paper §V-A).
    pts = np.array([[1, math.log2(n), math.log2(m)] for (n, m) in TABLE_I])
    d = np.array([TABLE_I[k][0] for k in TABLE_I])
    coef, *_ = np.linalg.lstsq(pts, d, rcond=None)
    return float(coef[0] + coef[1] * math.log2(N) + coef[2] * math.log2(M))


def pe_area_um2(N: int, M: int) -> float:
    """Power-proxy area scaling, calibrated on the two published array areas.

    area(N,M) = a_scalar * (p(N,M)/p(1,1))^gamma with gamma fit so that
    area(4,8) matches the Fig-8 16x16 KAN-SAs calibration point.
    """
    if N == 1 and M == 1:
        return _A_SCALAR_UM2
    ratio_cal = _A_NM_48_UM2 / _A_SCALAR_UM2
    pow_cal = pe_power_mw(4, 8) / pe_power_mw(1, 1)
    gamma = math.log(ratio_cal) / math.log(pow_cal)
    return _A_SCALAR_UM2 * (pe_power_mw(N, M) / pe_power_mw(1, 1)) ** gamma


# ------------------------------- workloads ---------------------------------


@dataclasses.dataclass(frozen=True)
class GEMMWorkload:
    """One KAN (or MLP) GEMM: (BS, K) @ (K*, N_out) with basis (G, P).

    ``kan=True`` means the left matrix is B-spline activations B
    (K* = K*(G+P), density (P+1)/(G+P)); ``kan=False`` is a dense MLP GEMM.
    """

    name: str
    BS: int
    K: int
    N_out: int
    G: int = 5
    P: int = 3
    kan: bool = True

    @property
    def M(self) -> int:
        return self.G + self.P

    @property
    def N(self) -> int:
        return self.P + 1

    @property
    def useful_macs(self) -> float:
        nnz = self.N if self.kan else 1
        return float(self.BS) * self.K * nnz * self.N_out


@dataclasses.dataclass(frozen=True)
class SAConfig:
    R: int
    C: int
    kind: str = "scalar"    # "scalar" | "nm"
    N: int = 4              # vector lanes (N:M PEs only)
    M: int = 8

    def area_mm2(self) -> float:
        if self.kind == "scalar":
            a = self.R * self.C * _A_SCALAR_UM2
        else:
            a = self.R * self.C * pe_area_um2(self.N, self.M)
        return (a + self.R * BSPLINE_UNIT_AREA_UM2) / 1e6

    def power_mw(self) -> float:
        p = pe_power_mw(1, 1) if self.kind == "scalar" else pe_power_mw(self.N, self.M)
        return self.R * self.C * p


@dataclasses.dataclass(frozen=True)
class SAResult:
    cycles: float
    useful_macs: float
    mac_slots: float

    @property
    def utilization(self) -> float:
        return self.useful_macs / self.mac_slots


def run_workload(sa: SAConfig, wl: GEMMWorkload, fill_drain: bool = False) -> SAResult:
    """Map one GEMM onto the array; returns cycles + utilization.

    ``fill_drain`` adds the (R + C - 1) systolic pipeline fill/drain per tile
    pass (runtime plots); the paper's utilization metric excludes it (the
    model then reproduces Fig 8's 99.25% MNIST-KAN figure exactly).
    """
    if sa.kind == "scalar":
        rows = wl.K * wl.M if wl.kan else wl.K
        lanes = 1
    else:
        if wl.kan and wl.N > sa.N:
            raise ValueError(
                f"array lanes N={sa.N} cannot host P+1={wl.N} non-zeros"
            )
        # One vector row per feature for KAN; N dense rows packed otherwise.
        rows = wl.K if wl.kan else math.ceil(wl.K / sa.N)
        lanes = sa.N
    row_tiles = math.ceil(rows / sa.R)
    col_tiles = math.ceil(wl.N_out / sa.C)
    per_tile = wl.BS + (sa.R + sa.C - 1 if fill_drain else 0)
    cycles = row_tiles * col_tiles * per_tile
    slots = sa.R * sa.C * lanes * cycles
    return SAResult(cycles=float(cycles), useful_macs=wl.useful_macs, mac_slots=float(slots))


def run_suite(
    sa: SAConfig, workloads: list[GEMMWorkload], fill_drain: bool = False
) -> SAResult:
    """Aggregate utilization/cycles across a workload list (paper Figs 7-8
    average; utilization aggregates as total-useful / total-slots)."""
    res = [run_workload(sa, w, fill_drain) for w in workloads]
    return SAResult(
        cycles=float(sum(r.cycles for r in res)),
        useful_macs=float(sum(r.useful_macs for r in res)),
        mac_slots=float(sum(r.mac_slots for r in res)),
    )


def normalized_energy(N: int, M: int) -> float:
    """Table I 'Normalized Energy': an N:M PE finishes a typical KAN workload
    in (G+P)=M-fold fewer cycles than the scalar PE at the power of Table I.

    E_norm = (p(N,M)/p(1,1)) * (1/M) — reproduces the published row exactly.
    """
    return pe_power_mw(N, M) / pe_power_mw(1, 1) / M


# --------------------------- ArKANe comparison -----------------------------


def arkane_cycles(n_inputs: int, G: int, P: int) -> float:
    """Paper §V-B: (P+1)*PE_latency + G + P - 1 + n_inputs."""
    return (P + 1) * FPMAX_FMA_LATENCY + G + P - 1 + n_inputs


def kansas_bspline_cycles(n_inputs: int, n_units: int) -> float:
    """Tabulated units: 1 cycle per input per unit, n_units in parallel."""
    return math.ceil(n_inputs / n_units)


def arkane_equiv_units(P: int = 3) -> int:
    """How many 450 um^2 B-spline units fit in ArKANe's (P+1) FMA area."""
    return int((P + 1) * FPMAX_FMA_AREA_MM2 * 1e6 // BSPLINE_UNIT_AREA_UM2)


# --------------------------- Table II workloads ----------------------------


def _mlp_chain(name, layers, G, P, BS, kan=True):
    return [
        GEMMWorkload(f"{name}.l{i}", BS, layers[i], layers[i + 1], G, P, kan)
        for i in range(len(layers) - 1)
    ]


def resnet18_convkan_gemms(G: int = 3, P: int = 3, img: int = 32, BS: int = 1):
    """ResKAN18: the 20 conv layers of ResNet-18 as im2col KAN GEMMs
    (paper Table II; CIFAR-10 stem). BS folds batch x output positions."""
    shapes = [("conv1", 3, 64, 3, img // 1)]
    cfg = [(64, 64)] * 4 + [(64, 128)] + [(128, 128)] * 3 + \
          [(128, 256)] + [(256, 256)] * 3 + [(256, 512)] + [(512, 512)] * 3
    spatial = [img] * 5 + [img // 2] * 4 + [img // 4] * 4 + [img // 8] * 4
    for i, ((cin, cout), s) in enumerate(zip(cfg, spatial)):
        shapes.append((f"conv{i+2}", cin, cout, 3, s))
    # three 1x1 downsample convs
    for i, (cin, cout, s) in enumerate([(64, 128, img // 2), (128, 256, img // 4), (256, 512, img // 8)]):
        shapes.append((f"down{i}", cin, cout, 1, s))
    return [
        GEMMWorkload(f"ResKAN18.{n}", BS * s * s, cin * k * k, cout, G, P, True)
        for (n, cin, cout, k, s) in shapes
    ]


def paper_workloads(BS: int = 64, fixed_gp: tuple[int, int] | None = None):
    """The Table II application suite. ``fixed_gp`` overrides per-app (G, P)
    as in Fig 7 ('parameters are fixed as ... G=5 and P=3')."""
    def gp(g, p):
        return fixed_gp if fixed_gp is not None else (g, p)

    apps: dict[str, list[GEMMWorkload]] = {}
    apps["5G-STARDUST"] = _mlp_chain("5G", [168, 40, 40, 40, 24], *gp(5, 3), BS)
    apps["Catch22-KAN"] = _mlp_chain("Catch22", [22, 10], *gp(3, 3), BS)
    apps["CF-KAN"] = sum(
        (_mlp_chain(f"CF{x}", [x, 512, x], *gp(2, 3), BS) for x in (2810, 34395, 6969)),
        [],
    )
    apps["U-KAN"] = (
        _mlp_chain("UKAN.a", [512, 1024, 512], *gp(5, 3), BS)
        + _mlp_chain("UKAN.b", [512, 512], *gp(5, 3), BS)
    )
    apps["GKAN"] = sum(
        (
            _mlp_chain(f"GKAN{g}{p}", ls, *gp(g, p), BS)
            for ls in ([200, 16, 7], [100, 20, 7])
            for (g, p) in [(2, 1), (3, 2), (3, 3)]
        ),
        [],
    )
    apps["Prefetcher"] = _mlp_chain("Prefetcher", [5, 64, 128], *gp(4, 3), BS)
    apps["MNIST-KAN"] = _mlp_chain("MNIST", [784, 64, 10], *gp(10, 3), BS)
    g, p = gp(3, 3)
    apps["ResKAN18"] = resnet18_convkan_gemms(g, p, BS=max(1, BS // 32))
    return apps
