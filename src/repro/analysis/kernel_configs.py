"""Kernel-config validator (kanlint KL2xx).

The autotuner (``kernels/autotune.py``) is the single source of tile
configs for every Pallas kernel — candidate spaces, the measured DEFAULTS
table, and the JSON measurement cache.  A config that oversubscribes VMEM
or violates dtype tiling alignment does not fail *here* on the CPU
container (interpret mode executes anything); it fails on the first real
TPU run, long after the PR merged.  This validator makes those configs
fail **lint** instead:

* **KL201 VMEM budget** — per-grid-step tile footprint (double-buffered
  input/output blocks + the fp32 scratch accumulator) must fit the ~16 MiB
  core VMEM, and the contraction width ``bk·unit`` must respect the shared
  ``_MAX_CONTRACT`` budget (DESIGN.md §2/§2a).
* **KL202 dtype tiling alignment** (TPU only) — batch tiles ``bb`` must be
  sublane-aligned for the dtype (fp32 8, bf16 16, int8 32) and output
  tiles ``bn`` lane-aligned (128).
* **KL203 grid fit** — tiles must not exceed the minimally padded problem
  dims (an oversized tile means a grid that never covers its block).

Checked surfaces: every registered kernel's candidate space and resolved
defaults over a representative problem suite (registry:
``kernels/ops.py:KERNELS``), plus every entry of the measurement cache the
environment points at (``$KAN_SAS_AUTOTUNE_CACHE``) — a hand-edited or
stale cache entry is exactly as dangerous as a bad default.
"""

from __future__ import annotations

import os

import jax.numpy as jnp

from repro.analysis.findings import Finding
from repro.kernels import autotune as tune

VMEM_BYTES = 16 * 2**20       # per-core VMEM (Pallas guide)
LANE = 128                     # last-dim tiling granularity on TPU

# Representative problems (BS, K, N): serving prefill, decode, and a small
# shape near the alignment boundaries.  M/nnz come from the kernel registry.
PROBLEM_SUITE = [(256, 512, 1024), (8, 256, 1024), (64, 64, 128)]


def _autotune_relpath() -> str:
    path = tune.__file__
    rel = os.path.relpath(path)
    return rel.replace(os.sep, "/") if not rel.startswith("..") else path


def _line_of(marker: str) -> int:
    try:
        with open(tune.__file__) as fh:
            for i, line in enumerate(fh, start=1):
                if marker in line:
                    return i
    except OSError:
        pass
    return 1


def tile_vmem_bytes(
    kernel: str, tiles: tune.Tiles, M: int, dtype, *,
    has_base: bool = True, out_bytes: int | None = None,
) -> int:
    """Per-grid-step VMEM footprint of one (bb, bn, bk) tiling.

    Blocks per step: x ``(bb, bk)``, coeff ``(bk·M, bn)`` dense-band or
    ``(bk, M, bn)`` sparse (same element count), optional base ``(bk, bn)``,
    out ``(bb, bn)``, fp32 scratch accumulator ``(bb, bn)`` (the sparse and
    int8 kernels carry a second index/int32 scratch of the same shape).
    Input/output blocks are double-buffered (×2) for the async copy
    pipeline; scratch is not.
    """
    bb, bn, bk = tiles
    e = jnp.dtype(dtype).itemsize
    oe = out_bytes if out_bytes is not None else e
    blocks = bb * bk * e + bk * M * bn * e + bb * bn * oe
    if has_base:
        blocks += bk * bn * e
    scratch = bb * bn * 4
    if tune.is_sparse_kernel(kernel) or "int8" in kernel:
        scratch += bb * bn * 4
    return 2 * blocks + scratch


def validate_tiles(
    kernel: str, tiles: tune.Tiles, BS: int, K: int, N: int, M: int,
    dtype, backend: str, nnz: int | None, *, origin: str,
    has_base: bool = True, out_bytes: int | None = None,
    path: str | None = None, line: int = 1,
) -> list[Finding]:
    """KL201/202/203 for one concrete tiling; ``origin`` names the config
    source (candidate space / defaults / cache entry) in the message."""
    path = path or _autotune_relpath()
    bb, bn, bk = tiles
    what = (f"{origin}: {kernel} tiles {bb}x{bn}x{bk} for "
            f"BS={BS} K={K} N={N} M={M} dtype={jnp.dtype(dtype).name} "
            f"backend={backend}")
    out: list[Finding] = []
    if min(bb, bn, bk) < 1:
        out.append(Finding("KL203", path, line, f"{what}: non-positive tile",
                           "tiles must be >= 1"))
        return out
    unit = tune._contract_unit(kernel, M, nnz)
    if bk * unit > tune._MAX_CONTRACT:
        out.append(Finding(
            "KL201", path, line,
            f"{what}: contraction width bk*{unit}={bk * unit} exceeds the "
            f"shared budget {tune._MAX_CONTRACT}",
            "shrink bk or widen the budget deliberately in autotune.py",
        ))
    if backend == "tpu":
        vmem = tile_vmem_bytes(kernel, tiles, M, dtype,
                               has_base=has_base, out_bytes=out_bytes)
        if vmem > VMEM_BYTES:
            out.append(Finding(
                "KL201", path, line,
                f"{what}: tile VMEM footprint {vmem} B exceeds the "
                f"{VMEM_BYTES} B core budget",
                "shrink bb/bn/bk until double-buffered blocks + scratch fit",
            ))
        sub = tune._SUBLANE.get(jnp.dtype(dtype).name, 8)
        if bb % sub:
            out.append(Finding(
                "KL202", path, line,
                f"{what}: bb={bb} violates the {jnp.dtype(dtype).name} "
                f"sublane granularity {sub}",
                f"round bb up to a multiple of {sub}",
            ))
        if bn % LANE:
            out.append(Finding(
                "KL202", path, line,
                f"{what}: bn={bn} violates the {LANE}-lane granularity",
                f"round bn up to a multiple of {LANE}",
            ))
    sub = tune._SUBLANE.get(jnp.dtype(dtype).name, 8)
    lane = LANE if backend == "tpu" else 8
    if bb > tune._round_up(BS, sub) or bn > tune._round_up(N, lane) or bk > K:
        out.append(Finding(
            "KL203", path, line,
            f"{what}: tile exceeds the padded problem "
            f"({tune._round_up(BS, sub)}, {tune._round_up(N, lane)}, {K})",
            "clamp tiles to the padded problem dims (grid blocks must "
            "cover real work)",
        ))
    return out


def _registry() -> dict:
    from repro.kernels.ops import KERNELS
    return KERNELS


def validate_candidate_spaces() -> list[Finding]:
    """Every registered kernel's candidate space over the problem suite —
    bad candidates fail lint, never compile."""
    line = _line_of("def candidate_tiles")
    out: list[Finding] = []
    for kernel, spec in _registry().items():
        for dtype in spec["dtypes"]:
            for backend in ("tpu", "cpu"):
                for BS, K, N in PROBLEM_SUITE:
                    cands = tune.candidate_tiles(
                        kernel, BS, K, N, spec["M"], dtype, backend,
                        nnz=spec.get("nnz"),
                    )
                    for tiles in cands:
                        out.extend(validate_tiles(
                            kernel, tiles, BS, K, N, spec["M"], dtype,
                            backend, spec.get("nnz"),
                            origin="candidate space",
                            has_base=spec.get("base", True),
                            out_bytes=spec.get("out_bytes"), line=line,
                        ))
    return out


def validate_defaults() -> list[Finding]:
    """The DEFAULTS table as ``get_tiles`` actually resolves it (the
    problem-clamp is part of the contract being validated — ONE definition,
    ``autotune.clamp_default``)."""
    line = _line_of("DEFAULTS: ")
    out: list[Finding] = []
    reg = _registry()
    for (kernel, backend) in tune.DEFAULTS:
        spec = reg.get(kernel)
        if spec is None:
            out.append(Finding(
                "KL204", _autotune_relpath(), line,
                f"kernel '{kernel}' has DEFAULTS but is not registered in "
                f"kernels/ops.py:KERNELS",
                "add a registry entry (dtypes, M, base, out_bytes) so its "
                "configs get validated",
            ))
            continue
        for dtype in spec["dtypes"]:
            for BS, K, N in PROBLEM_SUITE:
                tiles = tune.clamp_default(kernel, backend, BS, K, N, dtype)
                out.extend(validate_tiles(
                    kernel, tiles, BS, K, N, spec["M"], dtype, backend,
                    spec.get("nnz"), origin="DEFAULTS",
                    has_base=spec.get("base", True),
                    out_bytes=spec.get("out_bytes"), line=line,
                ))
    return out


def validate_measurement_cache() -> list[Finding]:
    """Every entry of the measurement cache currently in force
    (``$KAN_SAS_AUTOTUNE_CACHE`` / the default path): a hand-edited or
    stale winner reaches ``ops.py`` with zero compile-time checks, so it
    gets the same static validation as the in-repo tables."""
    cache = tune._load_cache()
    if not cache:
        return []
    path = os.path.relpath(tune.cache_path()).replace(os.sep, "/")
    reg = _registry()
    out: list[Finding] = []
    for key, entry in cache.items():
        tiles = tune._valid_tiles(entry)
        if tiles is None:
            out.append(Finding(
                "KL203", path, 1,
                f"cache entry {key!r}: malformed tiles {entry!r}",
                "delete the entry; get_tiles would ignore it anyway",
            ))
            continue
        try:
            kernel, rest = key.split("|", 1)
            kv = dict(p.split("=", 1) for p in rest.split("|"))
            BS, K, N, M = (int(kv[k]) for k in ("BS", "K", "N", "M"))
            dtype, backend = kv["dtype"], kv["backend"]
        except (ValueError, KeyError):
            out.append(Finding(
                "KL203", path, 1,
                f"cache entry {key!r}: unparseable problem key",
                "keys come from autotune.problem_key; delete foreign entries",
            ))
            continue
        spec = reg.get(kernel, {})
        out.extend(validate_tiles(
            kernel, tiles, BS, K, N, M, dtype, backend, spec.get("nnz"),
            origin="measurement cache", has_base=spec.get("base", True),
            out_bytes=spec.get("out_bytes"), path=path,
        ))
    return out


def validate_all() -> list[Finding]:
    return (
        validate_candidate_spaces()
        + validate_defaults()
        + validate_measurement_cache()
    )
