"""kanlint: static analysis + contract enforcement for this repo.

Four rule families (DESIGN.md §8 is the invariant catalogue):

* **KL1xx AST lints** (``ast_rules.py``) — jit donation, host-sync,
  float64-on-device-path, impure-traced-function checks over ``src/``;
* **KL2xx kernel-config validator** (``kernel_configs.py``) — autotuner
  candidate spaces / defaults / measurement-cache entries against VMEM,
  tiling-alignment, and grid budgets;
* **KL105 sharding audit** (``sharding_audit.py``) — public cache-mutating
  model entry points must thread ``ShardingCtx`` or be allowlisted;
* **retrace sentinel** (``retrace.py``) — runtime compile counting per
  (name, abstract signature), exported by the serving engine as
  ``last_serve_stats["compiles"]``.

Drivers: ``python -m repro.analysis --check src`` (CI) and
``python -m repro.launch.lint`` (the launcher-flavoured CLI).  Suppression:
``# kanlint: ignore[KLxxx]`` pragmas on the flagged line, and the
checked-in ``kanlint.baseline.json`` for accepted pre-existing findings
(CI fails only on findings not in it).

This module stays import-light: the engine imports ``analysis.retrace`` on
its hot path, so rule modules load lazily inside :func:`run_check`.
"""

from __future__ import annotations

import os

DEFAULT_BASELINE = "kanlint.baseline.json"


def collect_py_files(paths: list[str]) -> list[str]:
    out: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(
                d for d in dirs
                if d not in ("__pycache__", ".git") and not d.startswith(".")
            )
            out.extend(
                os.path.join(root, f) for f in sorted(files)
                if f.endswith(".py")
            )
    return out


def _rel(path: str) -> str:
    rel = os.path.relpath(path)
    return (path if rel.startswith("..") else rel).replace(os.sep, "/")


def run_check(
    paths: list[str],
    baseline_path: str | None = None,
    kernel_validator: bool = True,
) -> dict:
    """Run every rule family; returns a report dict:
    ``{"new": [Finding], "baselined": [Finding], "files": int}``."""
    from repro.analysis import ast_rules, findings, sharding_audit

    all_findings = []
    pragmas_by_path: dict[str, dict] = {}
    files = collect_py_files(paths)
    for path in files:
        rel = _rel(path)
        try:
            with open(path, encoding="utf-8") as fh:
                source = fh.read()
        except (OSError, UnicodeDecodeError):
            continue
        pragmas_by_path[rel] = findings.file_pragmas(source)
        all_findings.extend(ast_rules.lint_source(source, rel))
        all_findings.extend(sharding_audit.audit_source(source, rel))
    if kernel_validator:
        from repro.analysis import kernel_configs

        all_findings.extend(kernel_configs.validate_all())
    kept = findings.apply_pragmas(all_findings, pragmas_by_path)
    baseline = findings.load_baseline(baseline_path or DEFAULT_BASELINE)
    new, old = findings.split_baselined(kept, baseline)
    return {"new": new, "baselined": old, "files": len(files)}
