"""AST lints for JAX footguns (kanlint KL1xx).

Pure-``ast`` passes — no imports of the scanned code, so the linter can
judge a broken tree.  Rules (DESIGN.md §8):

* **KL101 missing donation** — a jitted callable takes a mutable-pytree
  argument (``caches``/``pool``/``view``/...) that is not listed in
  ``donate_argnums``.  Serving mutates KV in place; forgetting the donation
  silently doubles peak cache memory.
* **KL102 host sync** — ``np.asarray``/``np.array``/``float()``/``.item()``
  applied to a value produced by a jitted callable, outside a ``return``
  statement.  Each one is a blocking device->host transfer; hot loops must
  batch reads through the one sanctioned ``jax.device_get`` call.
* **KL103 float64 on a device path** — ``np.float64``/``jnp.float64``
  tokens inside traced functions or under the device-path packages
  (``models``/``kernels``/``serve``/``dist``).  x64 is disabled; a float64
  constant promotes on host and truncates on device, so these are at best
  dead precision and at worst a host/device divergence.  Host-side
  precompute (``core/`` knot/LUT construction) is deliberately out of
  scope.
* **KL104 impure traced function** — ``time.*``/``random.*``/
  ``np.random.*``/``datetime.*`` called inside a function passed to a
  tracing combinator (``jit``/``scan``/``vmap``/``grad``/``pallas_call``).
  These execute ONCE at trace time and freeze into the program — a classic
  silent-staleness bug.

Resolution machinery shared by the rules: jit-site detection (including the
engine's local ``_jit`` helper and ``analysis.retrace.counting`` wrappers,
which are unwrapped transparently), lambda/def/method resolution through
lexical scopes, and literal ``donate_argnums`` parsing.
"""

from __future__ import annotations

import ast

from repro.analysis.findings import Finding

# Call targets that wrap a python callable in a compiled program.  The
# first positional argument is the traced function.
JIT_NAMES = {"jax.jit", "jit", "_jit", "pjit", "jax.pjit"}
TRACE_NAMES = JIT_NAMES | {
    "jax.lax.scan", "lax.scan", "jax.vmap", "vmap", "jax.pmap",
    "jax.grad", "grad", "jax.checkpoint", "jax.remat", "checkpoint",
    "pl.pallas_call", "pallas_call", "shard_map",
}
# Transparent wrappers: counting(fn, name, registry) from analysis.retrace
# (and the engine's local `_count` alias for it) preserves the signature,
# so lint through it to the real callable.
TRANSPARENT_WRAPPERS = {"counting", "retrace.counting", "_count"}

# Argument names that, by repo convention, bind the big mutable pytrees
# (KV caches, block pools, gathered views).
DONATABLE_PARAMS = {
    "cache", "caches", "pool", "pools", "view", "views", "kv", "cache_ckv",
    "draft_caches",   # the speculative drafter's dense KV (DESIGN.md §9)
}

# KL102: host-readback callables and the sanctioned batch-transfer API.
READBACK_FUNCS = {
    "np.asarray", "np.array", "numpy.asarray", "numpy.array", "float",
}
# KL103: float64 tokens and the directories where device code lives.
F64_TOKENS = {"np.float64", "numpy.float64", "jnp.float64", "jax.numpy.float64"}
DEVICE_PATH_DIRS = {"models", "kernels", "serve", "dist"}
# KL104: modules whose calls are frozen-at-trace-time side effects.
IMPURE_ROOTS = {"time", "random", "datetime"}
IMPURE_PREFIXES = ("np.random.", "numpy.random.")


def _dotted(node: ast.AST) -> str | None:
    """'jax.jit' for Attribute chains, 'jit' for Names, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def _annotate_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._kl_parent = node  # type: ignore[attr-defined]


def _parents(node: ast.AST):
    cur = getattr(node, "_kl_parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "_kl_parent", None)


def _resolve_name(name: str, site: ast.AST) -> ast.FunctionDef | None:
    """Find the def a Name refers to, nearest lexical scope first."""
    for scope in _parents(site):
        if isinstance(scope, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef, ast.Module)):
            for stmt in getattr(scope, "body", []):
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and stmt.name == name:
                    return stmt
    return None


def _resolve_self_attr(attr: str, site: ast.AST) -> ast.FunctionDef | None:
    """self.X -> method X of the enclosing class."""
    for scope in _parents(site):
        if isinstance(scope, ast.ClassDef):
            for stmt in scope.body:
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and stmt.name == attr:
                    return stmt
    return None


def _unwrap_transparent(node: ast.AST) -> ast.AST:
    while isinstance(node, ast.Call) and \
            _dotted(node.func) in TRANSPARENT_WRAPPERS and node.args:
        node = node.args[0]
    return node


def _wrapped_params(
    fn_arg: ast.AST, site: ast.AST
) -> tuple[list[str], ast.AST | None]:
    """Resolve a jit site's first argument to (param names, body node).

    Bound-method references (``self.X``) drop the leading ``self`` — jit
    argnums index the *call-time* arguments.  Unresolvable targets return
    ``([], None)`` (no finding: never guess).
    """
    fn_arg = _unwrap_transparent(fn_arg)
    if isinstance(fn_arg, ast.Lambda):
        return [a.arg for a in fn_arg.args.args], fn_arg
    target = None
    if isinstance(fn_arg, ast.Name):
        target = _resolve_name(fn_arg.id, site)
    elif isinstance(fn_arg, ast.Attribute) and \
            isinstance(fn_arg.value, ast.Name) and fn_arg.value.id == "self":
        target = _resolve_self_attr(fn_arg.attr, site)
    if target is None:
        return [], None
    params = [a.arg for a in target.args.args]
    if params and params[0] == "self":
        params = params[1:]
    return params, target


def _literal_argnums(call: ast.Call, kw_name: str) -> set[int] | None:
    """Parse ``donate_argnums=(2,)``-style keywords.  Returns None when the
    keyword exists but is not a literal (rule then abstains)."""
    for kw in call.keywords:
        if kw.arg == kw_name:
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return {v.value}
            if isinstance(v, ast.Tuple) and all(
                isinstance(e, ast.Constant) and isinstance(e.value, int)
                for e in v.elts
            ):
                return {e.value for e in v.elts}
            return None
    return set()


def _jit_sites(tree: ast.AST) -> list[tuple[ast.Call, ast.AST]]:
    """Every (jit call, wrapped-fn expression) in the module, covering both
    ``x = jax.jit(fn, ...)`` calls and ``@jax.jit`` / ``@partial(jax.jit,
    ...)`` decorators (the decorator's "first argument" is the def)."""
    sites: list[tuple[ast.Call, ast.AST]] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _dotted(node.func) in JIT_NAMES \
                and node.args:
            sites.append((node, node.args[0]))
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call):
                    d = _dotted(dec.func)
                    if d in JIT_NAMES:
                        sites.append((dec, _def_ref(node)))
                    elif d in ("functools.partial", "partial") and dec.args \
                            and _dotted(dec.args[0]) in JIT_NAMES:
                        sites.append((dec, _def_ref(node)))
                elif _dotted(dec) in JIT_NAMES:
                    # bare ``@jax.jit``: no kwargs possible, so model it as
                    # a zero-keyword call site at the decorator's line
                    synthetic = ast.Call(func=dec, args=[], keywords=[])
                    synthetic.lineno = dec.lineno
                    sites.append((synthetic, _def_ref(node)))
    return sites


class _DefRef(ast.AST):
    """Marker wrapping a decorated def so _wrapped_params can use it."""
    _fields = ()

    def __init__(self, target):
        self.target = target


def _def_ref(node):
    return _DefRef(node)


# ---------------------------------------------------------------------------
# KL101 — missing donation
# ---------------------------------------------------------------------------


def check_donation(tree: ast.AST, path: str) -> list[Finding]:
    out: list[Finding] = []
    for call, fn_arg in _jit_sites(tree):
        if isinstance(fn_arg, _DefRef):
            target = fn_arg.target
            params = [a.arg for a in target.args.args]
            in_class = any(isinstance(p, ast.ClassDef)
                           for p in _parents(target))
            if in_class and params and params[0] == "self":
                params = params[1:]
        else:
            params, _ = _wrapped_params(fn_arg, call)
        if not params:
            continue
        donate = _literal_argnums(call, "donate_argnums")
        if donate is None:     # non-literal donate_argnums: abstain
            continue
        for i, p in enumerate(params):
            if p in DONATABLE_PARAMS and i not in donate:
                out.append(Finding(
                    "KL101", path, call.lineno,
                    f"jitted callable takes mutable pytree '{p}' "
                    f"(argnum {i}) without donating it",
                    f"add {i} to donate_argnums, or waive with "
                    f"'# kanlint: ignore[KL101]' if the buffer must "
                    f"outlive the call",
                ))
    return out


# ---------------------------------------------------------------------------
# KL102 — host readbacks on jitted results
# ---------------------------------------------------------------------------


def _jitted_attrs(cls: ast.ClassDef) -> set[str]:
    """Attribute names bound to jitted callables anywhere in the class:
    ``self.X = jax.jit(...)`` / ``self.X = _jit(...)``."""
    out: set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call) \
                and _dotted(node.value.func) in JIT_NAMES:
            for t in node.targets:
                if isinstance(t, ast.Attribute) and \
                        isinstance(t.value, ast.Name) and t.value.id == "self":
                    out.add(t.attr)
    return out


def _assign_targets(stmt: ast.Assign) -> list[str]:
    names: list[str] = []
    for t in stmt.targets:
        if isinstance(t, ast.Name):
            names.append(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            names.extend(e.id for e in t.elts if isinstance(e, ast.Name))
    return names


def _readback_calls(expr: ast.AST, tainted: set[str]) -> list[tuple[int, str]]:
    """(line, tainted name) for each host-sync call on a tainted value in
    ``expr``.  ``jax.device_get`` is the sanctioned batch transfer — its
    subtree is skipped entirely."""
    hits: list[tuple[int, str]] = []

    def visit(node: ast.AST) -> None:
        if isinstance(node, ast.Call):
            d = _dotted(node.func)
            if d == "jax.device_get":
                return      # sanctioned; don't descend into its args
            name = None
            if d in READBACK_FUNCS and node.args:
                a = node.args[0]
                if isinstance(a, ast.Name):
                    name = a.id
                elif isinstance(a, ast.Subscript) and \
                        isinstance(a.value, ast.Name):
                    name = a.value.id
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item":
                v = node.func.value
                if isinstance(v, ast.Name):
                    name = v.id
                elif isinstance(v, ast.Subscript) and \
                        isinstance(v.value, ast.Name):
                    name = v.value.id
            if name is not None and name in tainted:
                hits.append((node.lineno, name))
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(expr)
    return hits


def _scan_taint(fn: ast.FunctionDef, jitted: set[str], path: str,
                out: list[Finding]) -> None:
    """Linear taint walk over one function body.  Names assigned from
    ``self.<jitted>`` calls are device values; reassignment from anything
    else clears the taint.  ``return``ed readbacks are exempt — a single
    final transfer is the API's contract, not a hot-loop sync."""
    tainted: set[str] = set()

    def is_jitted_call(v: ast.AST) -> bool:
        return (
            isinstance(v, ast.Call)
            and isinstance(v.func, ast.Attribute)
            and isinstance(v.func.value, ast.Name)
            and v.func.value.id == "self"
            and v.func.attr in jitted
        )

    def flag(expr: ast.AST) -> None:
        for line, name in _readback_calls(expr, tainted):
            out.append(Finding(
                "KL102", path, line,
                f"host readback of jitted result '{name}' in a serving "
                f"loop (implicit device sync)",
                "batch reads through one jax.device_get((...)) per chunk",
            ))

    def walk_stmts(stmts: list[ast.stmt]) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                _scan_taint(stmt, jitted, path, out)   # fresh scope
                continue
            if isinstance(stmt, ast.Return):
                continue       # final-transfer exemption
            if isinstance(stmt, ast.Assign):
                flag(stmt.value)
                names = _assign_targets(stmt)
                if is_jitted_call(stmt.value) or (
                    isinstance(stmt.value, ast.Tuple) and any(
                        is_jitted_call(e) for e in stmt.value.elts)
                ):
                    tainted.update(names)
                else:
                    tainted.difference_update(names)
                continue
            # flag reads in other statement kinds, then recurse into blocks
            for field in ("value", "test", "iter"):
                sub = getattr(stmt, field, None)
                if sub is not None and isinstance(sub, ast.AST):
                    flag(sub)
            for field in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, field, None)
                if isinstance(sub, list) and sub and \
                        isinstance(sub[0], ast.stmt):
                    walk_stmts(sub)

    walk_stmts(fn.body)


def check_host_sync(tree: ast.AST, path: str) -> list[Finding]:
    out: list[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            jitted = _jitted_attrs(node)
            if not jitted:
                continue
            for stmt in node.body:
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    _scan_taint(stmt, jitted, path, out)
    return out


# ---------------------------------------------------------------------------
# KL103 — float64 on device paths
# ---------------------------------------------------------------------------


def _traced_functions(tree: ast.AST) -> set[ast.AST]:
    """Function/lambda nodes handed to tracing combinators (transitively
    via nested defs: a scan body inside a jitted method is inside its
    subtree, so one membership check per node suffices)."""
    traced: set[ast.AST] = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and node.args):
            continue
        if _dotted(node.func) not in TRACE_NAMES:
            continue
        fn_arg = _unwrap_transparent(node.args[0])
        if isinstance(fn_arg, ast.Lambda):
            traced.add(fn_arg)
        else:
            _, target = _wrapped_params(fn_arg, node)
            if target is not None:
                traced.add(target)
    # decorated defs: @jax.jit / @functools.partial(jax.jit, ...)
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                d = _dotted(dec.func) if isinstance(dec, ast.Call) \
                    else _dotted(dec)
                if d in JIT_NAMES or (
                    isinstance(dec, ast.Call)
                    and d in ("functools.partial", "partial") and dec.args
                    and _dotted(dec.args[0]) in JIT_NAMES
                ):
                    traced.add(node)
    return traced


def _in_traced(node: ast.AST, traced: set[ast.AST]) -> bool:
    if node in traced:
        return True
    return any(p in traced for p in _parents(node))


def check_float64(tree: ast.AST, path: str) -> list[Finding]:
    on_device_path = bool(DEVICE_PATH_DIRS & set(path.split("/")))
    traced = None
    out: list[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and _dotted(node) in F64_TOKENS:
            if not on_device_path:
                if traced is None:
                    traced = _traced_functions(tree)
                if not _in_traced(node, traced):
                    continue
            out.append(Finding(
                "KL103", path, node.lineno,
                f"float64 token '{_dotted(node)}' reachable from a device "
                f"path (x64 is disabled; this truncates under jit)",
                "compute in float32, or move the fp64 precompute to core/",
            ))
    return out


# ---------------------------------------------------------------------------
# KL104 — impure calls inside traced functions
# ---------------------------------------------------------------------------


def _impure_call(dotted: str | None) -> bool:
    if not dotted:
        return False
    if dotted.startswith(IMPURE_PREFIXES):
        return True
    root = dotted.split(".")[0]
    return root in IMPURE_ROOTS and "." in dotted


def check_traced_purity(tree: ast.AST, path: str) -> list[Finding]:
    traced = _traced_functions(tree)
    out: list[Finding] = []
    seen: set[tuple[int, str]] = set()
    for fn in traced:
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                d = _dotted(node.func)
                if _impure_call(d) and (node.lineno, d) not in seen:
                    seen.add((node.lineno, d))
                    out.append(Finding(
                        "KL104", path, node.lineno,
                        f"'{d}' called inside a traced function — it runs "
                        f"once at trace time and freezes into the program",
                        "hoist host randomness/clocks out of the traced "
                        "function; use jax.random with threaded keys",
                    ))
    return out


ALL_AST_RULES = (
    check_donation, check_host_sync, check_float64, check_traced_purity,
)


def lint_source(source: str, path: str) -> list[Finding]:
    """Run every KL1xx rule over one file's source."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("KL100", path, e.lineno or 1,
                        f"syntax error: {e.msg}", "fix the parse error")]
    _annotate_parents(tree)
    out: list[Finding] = []
    for rule in ALL_AST_RULES:
        out.extend(rule(tree, path))
    return out
