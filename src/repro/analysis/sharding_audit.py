"""Sharding-contract audit (kanlint KL105).

PR 5's mesh-native serving contract: **every public cache-mutating entry
point threads a ``ShardingCtx``** (a ``shard`` parameter) so freshly
written KV leaves are pinned to their logical-axes shardings — otherwise
GSPMD is free to gather a "distributed" cache to one device on the first
in-place update, silently, with no wrong answers to catch it.

The audit is purely syntactic: walk the model-layer modules
(``models/``), and for every public module-level function that takes a
cache-like parameter (``cache``/``caches``/``cache_ckv``/``pool``/...),
require either a ``shard`` parameter or an explicit allowlist entry (with
the reason recorded here, where the next reader will look).
"""

from __future__ import annotations

import ast

from repro.analysis.findings import Finding

CACHE_PARAMS = {"cache", "caches", "cache_ckv", "pool", "pools"}

# (module basename, function) -> reason the contract does not apply.
# Keep reasons honest: an entry here is a reviewed decision, not an escape
# hatch — read-only accessors and write *primitives* whose callers own the
# constraint are the only sanctioned shapes.
ALLOWLIST: dict[tuple[str, str], str] = {
    ("attention.py", "paged_view"): (
        "read-only gather; never writes the pool, nothing to pin"
    ),
    ("attention.py", "paged_write_span"): (
        "write primitive shared by every paged path; each caller pins via "
        "_constrain_cache immediately after (one constraint per step, not "
        "one per leaf write)"
    ),
}


def _audited(path: str) -> bool:
    """The contract governs the model layer (models/lm.py, blocks.py,
    attention.py and friends)."""
    return "models" in path.split("/")


def audit_source(source: str, path: str) -> list[Finding]:
    if not _audited(path):
        return []
    try:
        tree = ast.parse(source)
    except SyntaxError:
        return []    # ast_rules reports the parse error
    basename = path.rsplit("/", 1)[-1]
    out: list[Finding] = []
    for node in tree.body:           # module-level defs only
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if node.name.startswith("_"):
            continue                 # private helpers: callers own the pin
        params = {a.arg for a in node.args.args + node.args.kwonlyargs}
        if not (params & CACHE_PARAMS):
            continue
        if "shard" in params:
            continue
        if (basename, node.name) in ALLOWLIST:
            continue
        out.append(Finding(
            "KL105", path, node.lineno,
            f"public cache-mutating entry point '{node.name}' neither "
            f"threads ShardingCtx nor is allowlisted",
            "add a shard=None parameter and constrain written cache "
            "leaves, or add an ALLOWLIST entry with its reason in "
            "analysis/sharding_audit.py",
        ))
    return out
