"""CLI: ``python -m repro.analysis --check src`` (the CI lint tier).

Exit codes: 0 clean (or all findings baselined/waived), 1 new findings,
2 usage error.  ``--update-baseline`` rewrites the baseline to the current
finding set (the sanctioned way to accept pre-existing debt — shrink it,
never grow it casually; DESIGN.md §8).
"""

from __future__ import annotations

import argparse
import sys

from repro.analysis import DEFAULT_BASELINE, run_check


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/directories to lint (default: src)")
    ap.add_argument("--check", action="store_true",
                    help="explicit check mode (the default; kept so CI "
                         "invocations read as intent)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"baseline file (default: {DEFAULT_BASELINE})")
    ap.add_argument("--update-baseline", action="store_true",
                    help="accept current findings into the baseline")
    ap.add_argument("--no-kernel-validator", action="store_true",
                    help="skip the KL2xx kernel-config validation")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    paths = args.paths or ["src"]
    report = run_check(
        paths, baseline_path=args.baseline,
        kernel_validator=not args.no_kernel_validator,
    )
    new, old = report["new"], report["baselined"]
    if args.update_baseline:
        from repro.analysis.findings import save_baseline

        save_baseline(args.baseline, new + old)
        print(f"[kanlint] baseline updated: {len(new + old)} finding(s) "
              f"-> {args.baseline}")
        return 0
    for f in new:
        print(f.format())
    print(f"[kanlint] {report['files']} files: {len(new)} new finding(s), "
          f"{len(old)} baselined")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
