"""Finding model, inline waivers, and the checked-in baseline (kanlint).

Every rule in ``repro.analysis`` reports :class:`Finding`s — ``file:line``,
a stable rule id (``KL1xx`` AST lints, ``KL2xx`` kernel-config checks), a
one-line message, and a fix hint.  Two suppression mechanisms:

* **pragma** — a ``# kanlint: ignore[KL101]`` comment on the flagged line
  waives that rule there (use for findings that are *correct by intent*,
  e.g. a jitted gather whose input pytree must outlive the call);
* **baseline** — a checked-in JSON file of accepted pre-existing finding
  keys; CI fails only on findings NOT in it, so new violations never land
  while old ones are burned down.  Keys are line-number independent
  (``rule:path:message``) so unrelated edits don't churn the baseline.
"""

from __future__ import annotations

import dataclasses
import json
import re


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str       # "KL101"
    path: str       # repo-relative, posix separators
    line: int       # 1-based
    message: str
    hint: str

    @property
    def key(self) -> str:
        """Baseline identity: deliberately excludes the line number so the
        baseline survives edits above the finding."""
        return f"{self.rule}:{self.path}:{self.message}"

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}" + (
            f"  [fix: {self.hint}]" if self.hint else ""
        )


_PRAGMA = re.compile(r"#\s*kanlint:\s*ignore\[([A-Z0-9,\s]+)\]")


def file_pragmas(source: str) -> dict[int, set[str]]:
    """line (1-based) -> set of waived rule ids on that line."""
    out: dict[int, set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _PRAGMA.search(line)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def apply_pragmas(
    findings: list[Finding], pragmas_by_path: dict[str, dict[int, set[str]]]
) -> list[Finding]:
    kept = []
    for f in findings:
        waived = pragmas_by_path.get(f.path, {}).get(f.line, set())
        if f.rule not in waived:
            kept.append(f)
    return kept


def load_baseline(path: str) -> set[str]:
    """Accepted finding keys; a missing file is an empty baseline."""
    try:
        with open(path) as fh:
            data = json.load(fh)
    except OSError:
        return set()
    if not isinstance(data, dict):
        return set()
    keys = data.get("findings", [])
    return {k for k in keys if isinstance(k, str)}


def save_baseline(path: str, findings: list[Finding]) -> None:
    with open(path, "w") as fh:
        json.dump(
            {"findings": sorted({f.key for f in findings})}, fh, indent=1
        )
        fh.write("\n")


def split_baselined(
    findings: list[Finding], baseline: set[str]
) -> tuple[list[Finding], list[Finding]]:
    """-> (new findings that must fail CI, accepted baselined findings)."""
    new, old = [], []
    for f in findings:
        (old if f.key in baseline else new).append(f)
    return new, old
