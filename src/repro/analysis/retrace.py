"""Retrace sentinel: count compilations per (name, abstract signature).

``jax.jit`` silently retraces when an argument's abstract signature — shape,
dtype, pytree structure, or a static value — changes.  The serving engine's
whole performance story rests on *not* doing that mid-serve (PR 3: EOS
sweeps reuse the compiled decode chunk; admission prefill retraces once per
(group size, padded length) bucket).  This module makes those contracts
measurable:

* :func:`counting` wraps a python function *before* it is handed to
  ``jax.jit``.  The wrapper body executes only while jax is tracing (cache
  hits never re-enter python), so each execution is exactly one trace —
  i.e. one compiled program.  ``functools.wraps`` preserves the wrapped
  signature, so ``static_argnums``/``donate_argnums`` on the surrounding
  ``jit`` still resolve against the real parameters.
* :class:`RetraceRegistry` stores per-name signature->count maps and
  exports them as the ``last_serve_stats["compiles"]`` snapshot that the
  retrace regression tests (and ``BENCH_serve.json``) assert on.

The abstract signature is the pytree of ``dtype+shape`` strings for array
leaves (tracers included) and ``repr`` for static python values — the same
distinctions jit's own cache key draws, minus weak-type refinements.
"""

from __future__ import annotations

import functools

import jax


def _abstract_leaf(x) -> str:
    if hasattr(x, "shape") and hasattr(x, "dtype"):
        return f"{jax.numpy.dtype(x.dtype).name}{tuple(x.shape)}"
    return repr(x)


def abstract_signature(args: tuple, kwargs: dict) -> str:
    """Stable string key for one call's abstract signature (shapes/dtypes
    for arrays and tracers, ``repr`` for static values; pytree structure is
    part of the key because it is part of jit's)."""
    tree = jax.tree_util.tree_map(_abstract_leaf, (args, kwargs))
    return repr(tree)


class RetraceRegistry:
    """Per-name trace counters.  One registry per Engine."""

    def __init__(self) -> None:
        self._traces: dict[str, dict[str, int]] = {}

    def record(self, name: str, signature: str) -> None:
        sigs = self._traces.setdefault(name, {})
        sigs[signature] = sigs.get(signature, 0) + 1

    def programs(self, name: str) -> int:
        """Distinct abstract signatures traced under ``name`` — the number
        of compiled programs jit holds for it."""
        return len(self._traces.get(name, {}))

    def traces(self, name: str) -> int:
        """Total trace events under ``name`` (== programs unless something
        defeats jit's cache, e.g. a fresh wrapper per call)."""
        return sum(self._traces.get(name, {}).values())

    def snapshot(self) -> dict:
        """JSON-ready export: name -> {programs, traces, signatures}."""
        return {
            name: {
                "programs": len(sigs),
                "traces": sum(sigs.values()),
                "signatures": sorted(sigs),
            }
            for name, sigs in sorted(self._traces.items())
        }


def counting(fn, name: str, registry: RetraceRegistry):
    """Wrap ``fn`` so every *trace* (not every call) is recorded.

    Use as ``jax.jit(counting(fn, "decode_chunk", reg), ...)`` — the wrapper
    must sit INSIDE the jit: jit re-enters python only on cache miss, so the
    record call fires exactly once per compiled program.
    """

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        registry.record(name, abstract_signature(args, kwargs))
        return fn(*args, **kwargs)

    return wrapped
