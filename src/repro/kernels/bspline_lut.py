"""Pallas TPU kernel: the tabulated B-spline unit (paper §III-B, Fig. 5).

Computes, for a block of inputs, the ``P+1`` non-zero B-spline values and the
interval index ``k`` from a half-table of the cardinal B-spline — the
on-the-fly "BSpline block" that feeds the systolic array in the paper.

TPU adaptation: the ROM lookup becomes a **one-hot matmul** against the
(S x half) table resident in VMEM. A one-hot (block, S) @ (S, half) contraction
is MXU-native, branch-free, and implements *both* the direct and the
inverted-address fetch (the paper's ``~`` unit) as two small matmuls. The
alignment (Eq. 4) and interval search run as VPU vector code.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bspline import SplineGrid


def _bspline_lut_kernel(
    x_ref, lut_ref, vals_ref, k_ref, *, grid: SplineGrid, S: int, half: int
):
    P = grid.P
    x = x_ref[...]                                     # (block,)
    dtype = x.dtype
    # Align unit (Eq. 4): z = (x - t0)/delta.
    z = (x - dtype.type(grid.t0)) / dtype.type(grid.delta)
    # Compare unit: interval search, clipped to the in-domain range.
    k = jnp.clip(jnp.floor(z).astype(jnp.int32), P, grid.n_basis - 1)
    xa = jnp.clip(z - k.astype(dtype), 0.0, 1.0)
    addr = jnp.clip(jnp.round(xa * (S - 1)).astype(jnp.int32), 0, S - 1)
    addr_inv = (S - 1) - addr

    # ROM fetch as one-hot MXU matmuls (direct + inverted address).
    iota = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], S), 1)
    onehot_d = (addr[:, None] == iota).astype(dtype)
    onehot_i = (addr_inv[:, None] == iota).astype(dtype)
    lut = lut_ref[...]                                 # (S, half)
    direct = jnp.dot(onehot_d, lut, preferred_element_type=jnp.float32)
    mirror = jnp.dot(onehot_i, lut, preferred_element_type=jnp.float32)

    # Assemble the P+1 values in ascending basis order (Fig. 5:
    # "the corresponding values are reverse-packed").
    cols = []
    for i in range(P + 1):
        j = P - i
        cols.append(direct[:, j] if j < half else mirror[:, P - j])
    vals_ref[...] = jnp.stack(cols, axis=-1).astype(dtype)
    k_ref[...] = k


@functools.partial(
    jax.jit, static_argnames=("grid", "block", "interpret")
)
def bspline_lut_pallas(
    x: jax.Array,
    lut: jax.Array,
    grid: SplineGrid,
    block: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Tabulated evaluation of a flat vector of inputs.

    Returns ``(vals, k)`` with ``vals: (n, P+1)``, ``k: (n,) int32``.
    """
    (n,) = x.shape
    S, half = lut.shape
    n_pad = -n % block
    xp = jnp.pad(x, (0, n_pad), constant_values=grid.x_min)
    kernel = functools.partial(
        _bspline_lut_kernel, grid=grid, S=S, half=half
    )
    vals, k = pl.pallas_call(
        kernel,
        grid=(xp.shape[0] // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((S, half), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, grid.P + 1), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((xp.shape[0], grid.P + 1), x.dtype),
            jax.ShapeDtypeStruct((xp.shape[0],), jnp.int32),
        ],
        interpret=interpret,
    )(xp, lut)
    return vals[:n], k[:n]
