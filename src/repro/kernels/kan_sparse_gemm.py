"""Pallas TPU kernel: the compact N:M sparse spline GEMM (paper §IV-A/B).

The fused kernel (`kan_fused_gemm.py`) converts the B-spline's structured
N:M sparsity into *dense* MXU work: the ``P+1`` non-zero values are
scattered into the full ``M = G+P`` band and contracted ``bk·M`` wide, so
every tile pays ``M/(P+1)×`` more MACs — and streams ``M/(P+1)×`` more
coefficient rows — than the useful work requires.  That is exactly the
utilization gap the paper's N:M vector PE closes in hardware (§IV-A: 100%
vs ~30% for the conventional array).

This kernel is the software analogue of that PE.  Per input it contracts
only the ``P+1`` non-zero basis values against a *gathered* ``(P+1, N)``
slice of the coefficient tensor (the M-to-N multiplexer run forward,
``kernels/common.py: gather_coeff_slabs``), so

* MACs drop ``(G+P)/(P+1)×`` (2× at the default G=5/P=3, 3.25× for
  MNIST-KAN's G=10);
* the coefficient stream shrinks by the same factor: only the slab rows
  live inputs touch cross the memory boundary (exact at BS=1 decode — see
  DESIGN.md §2a for the accounting and the crossover vs the fused kernel).

Because the gathered slabs differ per batch row, the contraction is a
*batched* matvec ``(bb, 1, bk·(P+1)) @ (bb, bk·(P+1), bn)`` rather than one
shared GEMM — VPU-shaped work, which is precisely right for the
memory-bound small-batch/decode regime this kernel targets (the fused
kernel stays the large-batch path, where the MXU-aligned dense band wins).

Both variants follow the fused kernels' structure: grid
``(BS/bb, N/bn, K/bk)`` with the contraction innermost, fp32 (int32)
accumulation in a VMEM scratch tile, the base term ``ReLU(x) @ Wb`` (the
per-channel dequant multiply, for int8) fused as an epilogue on the
already-resident tile — one ``pallas_call`` per layer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bspline import SplineGrid
from repro.kernels.common import (
    CompilerParams,
    compact_basis_inblock,
    gather_coeff_slabs,
    int8_compact_values_inblock,
)


def _slab_contract(vals: jax.Array, slabs: jax.Array, acc_dtype) -> jax.Array:
    """Batched sparse contraction: ``(bb, bk, P+1) x (bb, bk, P+1, bn) ->
    (bb, bn)`` — each row contracts its own gathered slabs, ``bk·(P+1)``
    wide instead of the dense ``bk·M``."""
    bb = vals.shape[0]
    W = vals.shape[1] * vals.shape[2]                 # bk * (P+1)
    bn = slabs.shape[-1]
    out = jax.lax.dot_general(
        vals.reshape(bb, 1, W),
        slabs.reshape(bb, W, bn),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=acc_dtype,
    )
    return out[:, 0, :]


def _sparse_kernel(*refs, grid: SplineGrid, has_base: bool):
    if has_base:
        x_ref, c_ref, bw_ref, y_ref, acc_ref = refs
    else:
        x_ref, c_ref, y_ref, acc_ref = refs
        bw_ref = None
    x = x_ref[...]                                    # (bb, bk)
    vals, k = compact_basis_inblock(x, grid)          # f32 (bb, bk, P+1), i32
    c = c_ref[...]                                    # (bk, M, bn)

    # The N:M vector PE: gather each input's (P+1, bn) coefficient slab and
    # contract only the non-zero lanes — no dense band, no zero MACs.
    slabs = gather_coeff_slabs(c, k, grid.P)          # (bb, bk, P+1, bn)
    acc = _slab_contract(vals.astype(c.dtype), slabs, jnp.float32)

    if has_base:
        # Base-term epilogue (Eq. 1), same as the fused kernel: the x tile
        # is already in VMEM — one extra contraction, no extra HBM reads.
        xb = jnp.maximum(x, jnp.zeros((), x.dtype))
        acc = acc + jnp.dot(
            xb.astype(bw_ref.dtype), bw_ref[...],
            preferred_element_type=jnp.float32,
        )

    kk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = acc

    @pl.when(kk > 0)
    def _accumulate():
        acc_ref[...] = acc_ref[...] + acc

    @pl.when(kk == nk - 1)
    def _epilogue():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("grid", "bb", "bn", "bk", "interpret")
)
def kan_sparse_gemm_pallas(
    x: jax.Array,
    coeff: jax.Array,
    grid: SplineGrid,
    base_w: jax.Array | None = None,
    bb: int = 8,
    bn: int = 128,
    bk: int = 32,
    interpret: bool = False,
) -> jax.Array:
    """Sparse KAN layer. ``x: (BS, K)``, ``coeff: (K, M, N)``,
    ``base_w: (K, N) | None`` -> ``(BS, N)`` in ``x.dtype``.

    Numerically matches :func:`kan_fused_gemm_pallas` (same basis values,
    same fp32 accumulation; only the zero MACs are skipped).  Default tiles
    are decode-shaped: small ``bb``, wide ``bk`` (the sparse contraction is
    only ``bk·(P+1)`` wide, so a big ``bk`` keeps the per-step work dense).
    Inputs are padded to block multiples (padded features carry zero
    coefficients/base weights, hence contribute nothing).
    """
    BS, K = x.shape
    Kc, M, N = coeff.shape
    assert Kc == K and M == grid.n_basis
    has_base = base_w is not None
    pb, pk, pn = -BS % bb, -K % bk, -N % bn
    xp = jnp.pad(x, ((0, pb), (0, pk)), constant_values=grid.x_min)
    cp = jnp.pad(coeff, ((0, pk), (0, 0), (0, pn)))
    gb, gn, gk = (BS + pb) // bb, (N + pn) // bn, (K + pk) // bk

    in_specs = [
        pl.BlockSpec((bb, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, M, bn), lambda i, j, kk: (kk, 0, j)),
    ]
    operands = [xp, cp]
    if has_base:
        assert base_w.shape == (K, N), (base_w.shape, (K, N))
        bwp = jnp.pad(base_w.astype(coeff.dtype), ((0, pk), (0, pn)))
        in_specs.append(pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)))
        operands.append(bwp)

    y = pl.pallas_call(
        functools.partial(_sparse_kernel, grid=grid, has_base=has_base),
        grid=(gb, gn, gk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((BS + pb, N + pn), x.dtype),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*operands)
    return y[:BS, :N]


def _sparse_int8_kernel(
    *refs, grid: SplineGrid, S: int, qmax: int, lut_scale: int, has_scale: bool,
):
    if has_scale:
        xq_ref, cq_ref, scale_ref, y_ref, acc_ref = refs
    else:
        xq_ref, cq_ref, y_ref, acc_ref = refs
        scale_ref = None
    x_q = xq_ref[...].astype(jnp.int32)               # (bb, bk)

    # Shared integer Align/Compare + ROM-free fetch (bit-identical to the
    # dense-band int8 kernel), then the N:M gather instead of band scatter.
    bvals, k = int8_compact_values_inblock(x_q, grid, S, qmax, lut_scale)
    c = cq_ref[...].astype(jnp.int32)                 # (bk, M, bn)
    slabs = gather_coeff_slabs(c, k, grid.P)          # (bb, bk, P+1, bn)
    acc = _slab_contract(bvals, slabs, jnp.int32)

    kk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = acc

    @pl.when(kk > 0)
    def _accumulate():
        acc_ref[...] = acc_ref[...] + acc

    @pl.when(kk == nk - 1)
    def _epilogue():
        total = acc_ref[...]
        if has_scale:
            # Fused dequant epilogue, same as the dense-band int8 kernel.
            y_ref[...] = (
                total.astype(jnp.float32) * scale_ref[...]
            ).astype(y_ref.dtype)
        else:
            y_ref[...] = total


@functools.partial(
    jax.jit,
    static_argnames=("grid", "bb", "bn", "bk", "qmax", "S", "lut_scale",
                     "out_dtype", "interpret"),
)
def kan_sparse_int8_gemm_pallas(
    x_q: jax.Array,
    coeff_q: jax.Array,
    grid: SplineGrid,
    scale: jax.Array | None = None,
    bb: int = 8,
    bn: int = 128,
    bk: int = 32,
    qmax: int = 255,
    S: int = 256,
    lut_scale: int | None = None,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Integer sparse KAN GEMM — the N:M vector PE on the int8 datapath.

    Same contract as ``kan_int8_gemm_pallas`` (and bit-identical to it:
    identical integer address math, identical ROM values, int32
    accumulation — only the zero multiplies are skipped): returns the int32
    accumulator when ``scale is None``, else the dequantised ``out_dtype``
    via the fused epilogue.
    """
    assert lut_scale is not None, "pass lut_scale explicitly (see ops.py)"
    BS, K = x_q.shape
    Kc, M, N = coeff_q.shape
    assert Kc == K and M == grid.n_basis
    has_scale = scale is not None
    pb, pk, pn = -BS % bb, -K % bk, -N % bn
    xp = jnp.pad(x_q.astype(jnp.int32), ((0, pb), (0, pk)))
    cp = jnp.pad(coeff_q.astype(jnp.int8), ((0, pk), (0, 0), (0, pn)))
    gb, gn, gk = (BS + pb) // bb, (N + pn) // bn, (K + pk) // bk

    in_specs = [
        pl.BlockSpec((bb, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, M, bn), lambda i, j, kk: (kk, 0, j)),
    ]
    operands = [xp, cp]
    if has_scale:
        sp = jnp.pad(scale.astype(jnp.float32).reshape(1, N), ((0, 0), (0, pn)))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        operands.append(sp)

    y = pl.pallas_call(
        functools.partial(
            _sparse_int8_kernel, grid=grid, S=S, qmax=qmax,
            lut_scale=lut_scale, has_scale=has_scale,
        ),
        grid=(gb, gn, gk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct(
            (BS + pb, N + pn), out_dtype if has_scale else jnp.int32
        ),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*operands)
    return y[:BS, :N]
