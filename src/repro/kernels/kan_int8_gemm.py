"""Pallas TPU kernel: the integer-only KAN-SAs datapath (paper §III-B2, §V).

Implements the exact hardware pipeline of the paper in one fused kernel:

* integer Align + Compare (Eq. 5): ``u = (G+2P)(x_q - t_q0)``,
  ``k = u // 255``, ``addr = clip(u - 255k, 0, 255)`` — int32 arithmetic only;
* the uint8 ROM of Fig. 5, realised **without the ROM**: the table entries
  are by construction ``round(B_{0,P}(addr/(S-1) + c) · s)``, so the kernel
  evaluates that generating function directly with the shared compare-select
  Cox-de Boor code (:mod:`repro.kernels.common`) and rounds — bit-identical
  to the direct + inverted-address half-table fetch (verified by
  ``tests/test_kernels.py``), but O(P²) per element instead of the two
  O(S)-wide one-hot matmuls the previous revision used;
* the dense-band scatter (the M-to-N mux in reverse) shared with the
  floating-point kernel;
* int8 coefficient band, int32 accumulation (8-bit in / 32-bit out PEs of
  Table I). On a real TPU the int8 MXU path doubles throughput vs bf16;
* an optional **fused dequantisation epilogue**: the per-output-channel
  float multiply of [18] is applied to the int32 accumulator tile while it
  is still in VMEM, so the kernel emits the serving dtype directly and the
  int32 accumulator never touches HBM.

Without ``scale`` the raw int32 accumulator is returned (the bit-exact
contract the oracle tests check).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bspline import SplineGrid
from repro.kernels.common import (
    CompilerParams,
    band_scatter,
    int8_compact_values_inblock,
)


def _int8_kernel(
    *refs, grid: SplineGrid, S: int, qmax: int, lut_scale: int, has_scale: bool,
):
    if has_scale:
        xq_ref, cq_ref, scale_ref, y_ref, acc_ref = refs
    else:
        xq_ref, cq_ref, y_ref, acc_ref = refs
        scale_ref = None
    M = grid.n_basis
    x_q = xq_ref[...].astype(jnp.int32)               # (bb, bk)

    # Integer Align + Compare (Eq. 5) + ROM-free fetch (shared with the
    # sparse int8 kernel): bit-identical to the uint8 half-table.
    bvals, k = int8_compact_values_inblock(x_q, grid, S, qmax, lut_scale)

    # Dense-band scatter (the M-to-N mux in reverse) + int32 MXU GEMM.
    band = band_scatter(bvals, k, M)                  # (bb, bk, M) int32
    bb, bk = x_q.shape
    acc = jnp.dot(
        band.reshape(bb, bk * M), cq_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )

    kk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = acc

    @pl.when(kk > 0)
    def _accumulate():
        acc_ref[...] = acc_ref[...] + acc

    @pl.when(kk == nk - 1)
    def _epilogue():
        total = acc_ref[...]
        if has_scale:
            # Fused dequant: one float multiply per output channel while the
            # accumulator tile is still in VMEM (paper [18]); the int32
            # accumulator never reaches HBM.
            y_ref[...] = (
                total.astype(jnp.float32) * scale_ref[...]
            ).astype(y_ref.dtype)
        else:
            y_ref[...] = total


@functools.partial(
    jax.jit,
    static_argnames=("grid", "bb", "bn", "bk", "qmax", "S", "lut_scale",
                     "out_dtype", "interpret"),
)
def kan_int8_gemm_pallas(
    x_q: jax.Array,
    coeff_q: jax.Array,
    grid: SplineGrid,
    scale: jax.Array | None = None,
    bb: int = 128,
    bn: int = 128,
    bk: int = 16,
    qmax: int = 255,
    S: int = 256,
    lut_scale: int | None = None,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Integer fused KAN GEMM.

    ``x_q: (BS, K)`` uint8/int32 activations quantised over the extended
    domain; ``coeff_q: (K, M, N)`` int8; ``scale: (N,) float32 | None`` the
    per-output-channel dequant multiplier (typically
    ``coeff_scale / lut_scale``).

    Returns the int32 accumulator ``(BS, N)`` when ``scale is None``, else
    the dequantised ``(BS, N)`` in ``out_dtype`` (fused epilogue).
    """
    assert lut_scale is not None, (
        "pass lut_scale explicitly (resolve with "
        "repro.core.quantization.lut_value_scale OUTSIDE any jit trace)"
    )
    BS, K = x_q.shape
    Kc, M, N = coeff_q.shape
    assert Kc == K and M == grid.n_basis
    has_scale = scale is not None
    pb, pk, pn = -BS % bb, -K % bk, -N % bn
    xp = jnp.pad(x_q.astype(jnp.int32), ((0, pb), (0, pk)))
    cp = jnp.pad(coeff_q.astype(jnp.int8), ((0, pk), (0, 0), (0, pn)))
    c2 = cp.reshape((K + pk) * M, N + pn)
    gb, gn, gk = (BS + pb) // bb, (N + pn) // bn, (K + pk) // bk

    in_specs = [
        pl.BlockSpec((bb, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk * M, bn), lambda i, j, kk: (kk, j)),
    ]
    operands = [xp, c2]
    if has_scale:
        sp = jnp.pad(scale.astype(jnp.float32).reshape(1, N), ((0, 0), (0, pn)))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        operands.append(sp)

    y = pl.pallas_call(
        functools.partial(
            _int8_kernel, grid=grid, S=S, qmax=qmax,
            lut_scale=lut_scale, has_scale=has_scale,
        ),
        grid=(gb, gn, gk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct(
            (BS + pb, N + pn), out_dtype if has_scale else jnp.int32
        ),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*operands)
    return y[:BS, :N]


@functools.lru_cache(maxsize=32)
def _reference_lut(P: int, S: int, scale: int) -> np.ndarray:
    from repro.core.quantization import build_lut_u8

    return build_lut_u8(P, S, scale)


@functools.lru_cache(maxsize=8)
def _max_cardinal(P: int) -> float:
    from repro.core import bspline

    return float(bspline.cardinal_bspline(jnp.asarray((P + 1) / 2.0), P))


def resolve_lut_scale(lut_u8, grid: SplineGrid, S: int) -> int:
    """The ROM-free kernel reproduces ``build_lut_u8(P, S, scale)``; infer
    ``scale`` from a concrete table (and verify the table matches — any
    other table is rejected).  A traced table (inside an enclosing jit)
    cannot be inspected: the caller must pass ``lut_scale`` explicitly
    (``ops.kan_int8_gemm(..., lut_scale=...)``) for non-default scales.
    """
    from repro.core.quantization import lut_value_scale

    default = lut_value_scale(grid.P)
    try:
        concrete = np.asarray(lut_u8)
    except Exception:
        return default  # traced: default-scale contract
    # Infer: the table max is round(max(B_{0,P}) * scale).
    inferred = int(round(float(concrete.max()) / _max_cardinal(grid.P)))
    for scale in dict.fromkeys((default, inferred, inferred - 1, inferred + 1)):
        if scale > 0 and np.array_equal(concrete, _reference_lut(grid.P, S, scale)):
            return scale
    raise ValueError(
        "kan_int8_gemm computes the build_lut_u8 ROM in-kernel; the given "
        "table matches no integer value scale — arbitrary LUT tables are "
        "not supported"
    )
