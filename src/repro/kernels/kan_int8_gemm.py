"""Pallas TPU kernel: the integer-only KAN-SAs datapath (paper §III-B2, §V).

Implements the exact hardware pipeline of the paper in one fused kernel:

* integer Align + Compare (Eq. 5): ``u = (G+2P)(x_q - t_q0)``,
  ``k = u // 255``, ``addr = clip(u - 255k, 0, 255)`` — int32 arithmetic only;
* uint8 half-LUT fetch with the inverted-address ``~`` unit (Fig. 5),
  realised as one-hot int matmuls;
* int8 coefficient band, int32 accumulation (8-bit in / 32-bit out PEs of
  Table I). On a real TPU the int8 MXU path doubles throughput vs bf16.

Output is the raw int32 accumulator; dequantisation (one float multiply per
output channel, as in [18]) happens outside the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bspline import SplineGrid


def _int8_kernel(
    xq_ref, lut_ref, cq_ref, y_ref, *, grid: SplineGrid, bk: int, S: int,
    half: int, qmax: int,
):
    P, M = grid.P, grid.n_basis
    x_q = xq_ref[...].astype(jnp.int32)               # (bb, bk)

    # Integer Align + Compare units (paper Eq. 5).
    u = (grid.G + 2 * P) * x_q
    k = jnp.clip(u // qmax, P, M - 1)
    addr = jnp.clip(u - qmax * k, 0, qmax)
    addr = (addr * (S - 1)) // qmax
    addr_inv = (S - 1) - addr

    # uint8 ROM fetch via one-hot integer matmuls (direct + inverted).
    flat = addr.reshape(-1)
    flat_inv = addr_inv.reshape(-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, (flat.shape[0], S), 1)
    lut = lut_ref[...].astype(jnp.int32)              # (S, half)
    direct = jnp.dot(
        (flat[:, None] == iota).astype(jnp.int32), lut,
        preferred_element_type=jnp.int32,
    ).reshape(x_q.shape + (half,))
    mirror = jnp.dot(
        (flat_inv[:, None] == iota).astype(jnp.int32), lut,
        preferred_element_type=jnp.int32,
    ).reshape(x_q.shape + (half,))
    cols = []
    for i in range(P + 1):                            # ascending basis index
        j = P - i
        cols.append(direct[..., j] if j < half else mirror[..., P - j])
    bvals = jnp.stack(cols, axis=-1)                  # (bb, bk, P+1) int32

    # Dense-band scatter (the M-to-N mux in reverse) + int32 MXU GEMM.
    m_iota = jax.lax.broadcasted_iota(jnp.int32, x_q.shape + (M,), x_q.ndim)
    rel = m_iota - (k[..., None] - P)
    band = jnp.zeros(x_q.shape + (M,), jnp.int32)
    for i in range(P + 1):
        band = band + jnp.where(rel == i, bvals[..., i][..., None], 0)
    bb = x_q.shape[0]
    acc = jnp.dot(
        band.reshape(bb, bk * M), cq_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )

    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        y_ref[...] = acc

    @pl.when(kk > 0)
    def _acc():
        y_ref[...] = y_ref[...] + acc


@functools.partial(
    jax.jit, static_argnames=("grid", "bb", "bn", "bk", "qmax", "interpret")
)
def kan_int8_gemm_pallas(
    x_q: jax.Array,
    lut_u8: jax.Array,
    coeff_q: jax.Array,
    grid: SplineGrid,
    bb: int = 128,
    bn: int = 128,
    bk: int = 16,
    qmax: int = 255,
    interpret: bool = False,
) -> jax.Array:
    """Integer fused KAN GEMM.

    ``x_q: (BS, K)`` uint8/int32 activations quantised over the extended
    domain; ``lut_u8: (S, half)`` uint8; ``coeff_q: (K, M, N)`` int8.
    Returns the int32 accumulator ``(BS, N)``.
    """
    BS, K = x_q.shape
    Kc, M, N = coeff_q.shape
    assert Kc == K and M == grid.n_basis
    S, half = lut_u8.shape
    pb, pk, pn = -BS % bb, -K % bk, -N % bn
    xp = jnp.pad(x_q.astype(jnp.int32), ((0, pb), (0, pk)))
    cp = jnp.pad(coeff_q.astype(jnp.int8), ((0, pk), (0, 0), (0, pn)))
    c2 = cp.reshape((K + pk) * M, N + pn)
    gb, gn, gk = (BS + pb) // bb, (N + pn) // bn, (K + pk) // bk

    y = pl.pallas_call(
        functools.partial(
            _int8_kernel, grid=grid, bk=bk, S=S, half=half, qmax=qmax
        ),
        grid=(gb, gn, gk),
        in_specs=[
            pl.BlockSpec((bb, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((S, half), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((bk * M, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((BS + pb, N + pn), jnp.int32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(xp, lut_u8, c2)
    return y[:BS, :N]
