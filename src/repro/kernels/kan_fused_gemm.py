"""Pallas TPU kernel: fused KAN GEMM — the KAN-SAs array itself (paper §III-IV).

Computes the **whole** KAN layer of Eq. 1 in one kernel:

``Y[b, n] = sum_{j,m} B_m(x[b, j]) * C[j, m, n]  +  sum_j ReLU(x[b, j]) * Wb[j, n]``

**without ever materialising the B-spline activation matrix
``B : (BS, K*(G+P))`` in HBM**, and without a second GEMM for the base term.

This is the TPU rendering of the paper's two architectural moves:

* the B-spline unit "directly streams its values into the systolic array"
  (§III-A): here, each grid step evaluates the compact ``P+1`` non-zero
  values *in VMEM/registers* from the raw ``x`` tile;
* the N:M vector PE with its M-to-N multiplexer (§IV-B): the multiplexer
  becomes a branch-free compare-select that places the compact values into
  the dense band of an MXU tile (:func:`repro.kernels.common.band_scatter`).

The base term ``w_b · ReLU(x)`` of Eq. 1 rides along as an **epilogue
contraction on the same x tile**: the tile is already resident in VMEM for
the spline evaluation, so the base GEMM costs zero extra HBM reads of ``x``
and no second kernel launch.  HBM traffic drops from ``X + B + C + Y``
(dense-B baseline, plus another ``X + Wb + Y`` for a separate base GEMM) to
``X + C + Wb + Y`` — see DESIGN.md §2 for the roofline accounting.

Accumulation is float32 in a VMEM scratch tile regardless of the input
dtype (bf16 inputs hit the MXU in bf16 but never round the partial sums);
the output tile is written once, on the last contraction step.

Grid: ``(BS/bb, N/bn, K/bk)`` with the contraction dim innermost; the
accumulator stays resident in VMEM across the ``K`` sweep (standard Pallas
matmul revisiting pattern).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bspline import SplineGrid
from repro.kernels.common import (
    CompilerParams,
    band_scatter,
    compact_basis_inblock,
)


def _fused_kernel(*refs, grid: SplineGrid, has_base: bool):
    if has_base:
        x_ref, c_ref, bw_ref, y_ref, acc_ref = refs
    else:
        x_ref, c_ref, y_ref, acc_ref = refs
        bw_ref = None
    M = grid.n_basis
    x = x_ref[...]                                    # (bb, bk)
    vals, k = compact_basis_inblock(x, grid)          # f32 (bb, bk, P+1), i32

    # M-to-N multiplexer, run in reverse (paper §IV-B): place the compact
    # values into the dense band with compare-selects — no gathers.
    band = band_scatter(vals, k, M)                   # f32 (bb, bk, M)

    bb, bk = x.shape
    c = c_ref[...]                                    # (bk*M, bn)
    B_tile = band.reshape(bb, bk * M).astype(c.dtype)  # VMEM only, never HBM
    acc = jnp.dot(B_tile, c, preferred_element_type=jnp.float32)

    if has_base:
        # Base-term epilogue (Eq. 1): the x tile is already in VMEM — one
        # extra MXU contraction, zero extra HBM traffic for x.
        xb = jnp.maximum(x, jnp.zeros((), x.dtype))   # ReLU in input dtype
        acc = acc + jnp.dot(
            xb.astype(bw_ref.dtype), bw_ref[...],
            preferred_element_type=jnp.float32,
        )

    kk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = acc

    @pl.when(kk > 0)
    def _accumulate():
        acc_ref[...] = acc_ref[...] + acc

    @pl.when(kk == nk - 1)
    def _epilogue():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("grid", "bb", "bn", "bk", "interpret")
)
def kan_fused_gemm_pallas(
    x: jax.Array,
    coeff: jax.Array,
    grid: SplineGrid,
    base_w: jax.Array | None = None,
    bb: int = 128,
    bn: int = 128,
    bk: int = 16,
    interpret: bool = False,
) -> jax.Array:
    """Fused KAN layer. ``x: (BS, K)``, ``coeff: (K, M, N)``,
    ``base_w: (K, N) | None`` -> ``(BS, N)`` in ``x.dtype``.

    When ``base_w`` is given the base term ``ReLU(x) @ base_w`` is fused
    into the kernel epilogue — spline + base in a single ``pallas_call``.
    Block sizes default to MXU-friendly tiles (contraction width ``bk*M``);
    inputs are padded to block multiples (padded features carry zero
    coefficients/base weights, hence contribute nothing).
    """
    BS, K = x.shape
    Kc, M, N = coeff.shape
    assert Kc == K and M == grid.n_basis
    has_base = base_w is not None
    pb, pk, pn = -BS % bb, -K % bk, -N % bn
    xp = jnp.pad(x, ((0, pb), (0, pk)), constant_values=grid.x_min)
    cp = jnp.pad(coeff, ((0, pk), (0, 0), (0, pn)))
    c2 = cp.reshape((K + pk) * M, N + pn)
    gb, gn, gk = (BS + pb) // bb, (N + pn) // bn, (K + pk) // bk

    in_specs = [
        pl.BlockSpec((bb, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk * M, bn), lambda i, j, kk: (kk, j)),
    ]
    operands = [xp, c2]
    if has_base:
        assert base_w.shape == (K, N), (base_w.shape, (K, N))
        bwp = jnp.pad(base_w.astype(coeff.dtype), ((0, pk), (0, pn)))
        in_specs.append(pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)))
        operands.append(bwp)

    y = pl.pallas_call(
        functools.partial(_fused_kernel, grid=grid, has_base=has_base),
        grid=(gb, gn, gk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((BS + pb, N + pn), x.dtype),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*operands)
    return y[:BS, :N]
