"""Pallas TPU kernel: fused KAN GEMM — the KAN-SAs array itself (paper §III-IV).

Computes ``Y[b, n] = sum_{j,m} B_m(x[b, j]) * C[j, m, n]`` **without ever
materialising the B-spline activation matrix ``B : (BS, K*(G+P))`` in HBM**.

This is the TPU rendering of the paper's two architectural moves:

* the B-spline unit "directly streams its values into the systolic array"
  (§III-A): here, each grid step evaluates the compact ``P+1`` non-zero
  values *in VMEM/registers* from the raw ``x`` tile;
* the N:M vector PE with its M-to-N multiplexer (§IV-B): the multiplexer
  becomes a branch-free compare-select that places the compact values into
  the dense band of an MXU tile. Structured sparsity is thereby converted
  into MXU-aligned compute, and the HBM traffic drops from
  ``X + B + C + Y`` to ``X + C + Y`` — a ``(G+P)``-fold cut of the dominant
  activation stream (see EXPERIMENTS.md §Perf for the roofline accounting).

Grid: ``(BS/bb, N/bn, K/bk)`` with the contraction dim innermost; the output
tile stays resident in VMEM across the ``K`` sweep (standard Pallas matmul
revisiting pattern).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bspline import SplineGrid


def _compact_basis_inblock(x, grid: SplineGrid):
    """Exact compact N:M evaluation as branch-free vector code.

    Returns ``vals: x.shape + (P+1,)`` (ascending basis index) and ``k``.
    Identical math to :func:`repro.core.bspline.compact_basis`, written with
    only iota/where/arithmetic so it lowers cleanly inside a TPU kernel.
    """
    P = grid.P
    dtype = x.dtype
    z = (x - dtype.type(grid.t0)) / dtype.type(grid.delta)
    k = jnp.clip(jnp.floor(z).astype(jnp.int32), P, grid.n_basis - 1)
    xa = jnp.clip(z - k.astype(dtype), 0.0, 1.0)
    # Evaluate the cardinal B-spline at u_i = xa + (P - i), i = 0..P.
    # Since u_i in [P-i, P-i+1), the degree-0 coefficient vector for point i
    # is e_{P-i}: run the Cox-de Boor triangle on a (P+2)-wide band.
    offs = dtype.type(P) - jax.lax.broadcasted_iota(
        jnp.int32, xa.shape + (P + 1,), xa.ndim
    ).astype(dtype)
    u = xa[..., None] + offs                                    # (..., P+1)
    nseg = P + 2
    seg = jax.lax.broadcasted_iota(jnp.int32, u.shape + (nseg - 1,), u.ndim)
    b = jnp.where(
        (u[..., None] >= seg.astype(dtype)) & (u[..., None] < (seg + 1).astype(dtype)),
        dtype.type(1.0),
        dtype.type(0.0),
    )                                                           # (..., P+1, P+1)
    for p in range(1, P + 1):
        idx = jax.lax.broadcasted_iota(
            jnp.int32, u.shape + (nseg - 1 - p,), u.ndim
        ).astype(dtype)
        left = (u[..., None] - idx) / dtype.type(p) * b[..., :-1]
        right = (idx + dtype.type(p + 1) - u[..., None]) / dtype.type(p) * b[..., 1:]
        b = left + right
    return b[..., 0], k


def _fused_kernel(x_ref, c_ref, y_ref, *, grid: SplineGrid, bk: int):
    P, M = grid.P, grid.n_basis
    x = x_ref[...]                                    # (bb, bk)
    vals, k = _compact_basis_inblock(x, grid)         # (bb, bk, P+1), (bb, bk)

    # M-to-N multiplexer, run in reverse (paper §IV-B): place the compact
    # values into the dense band with compare-selects — no gathers.
    m_iota = jax.lax.broadcasted_iota(jnp.int32, x.shape + (M,), x.ndim)
    rel = m_iota - (k[..., None] - P)                 # (bb, bk, M)
    band = jnp.zeros(x.shape + (M,), x.dtype)
    for i in range(P + 1):
        band = band + jnp.where(rel == i, vals[..., i][..., None], x.dtype.type(0.0))

    bb = x.shape[0]
    B_tile = band.reshape(bb, bk * M)                 # (bb, bk*M) in VMEM only
    c = c_ref[...]                                    # (bk*M, bn)
    acc = jnp.dot(B_tile, c, preferred_element_type=jnp.float32)

    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        y_ref[...] = acc.astype(y_ref.dtype)

    @pl.when(kk > 0)
    def _acc():
        y_ref[...] = (y_ref[...].astype(jnp.float32) + acc).astype(y_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("grid", "bb", "bn", "bk", "interpret")
)
def kan_fused_gemm_pallas(
    x: jax.Array,
    coeff: jax.Array,
    grid: SplineGrid,
    bb: int = 128,
    bn: int = 128,
    bk: int = 16,
    interpret: bool = False,
) -> jax.Array:
    """Fused KAN GEMM. ``x: (BS, K)``, ``coeff: (K, M, N)`` -> ``(BS, N)``.

    Block sizes default to MXU-friendly tiles (contraction width ``bk*M``);
    inputs are padded to block multiples (padded features carry zero
    coefficients, hence contribute nothing).
    """
    BS, K = x.shape
    Kc, M, N = coeff.shape
    assert Kc == K and M == grid.n_basis
    pb, pk, pn = -BS % bb, -K % bk, -N % bn
    xp = jnp.pad(x, ((0, pb), (0, pk)), constant_values=grid.x_min)
    cp = jnp.pad(coeff, ((0, pk), (0, 0), (0, pn)))
    c2 = cp.reshape((K + pk) * M, N + pn)
    gb, gn, gk = (BS + pb) // bb, (N + pn) // bn, (K + pk) // bk

    y = pl.pallas_call(
        functools.partial(_fused_kernel, grid=grid, bk=bk),
        grid=(gb, gn, gk),
        in_specs=[
            pl.BlockSpec((bb, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk * M, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((BS + pb, N + pn), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(xp, c2)
    return y[:BS, :N]
