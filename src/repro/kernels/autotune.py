"""Tile autotuner for the KAN Pallas kernels (DESIGN.md §2).

The fused kernels tile the ``(BS, N, K)`` iteration space with
``(bb, bn, bk)`` blocks; the best tiling depends on the problem shape, the
dtype (sublane granularity) and the backend.  Rather than hard-coding
``128/128/16`` everywhere, :func:`get_tiles` resolves tiles in three steps:

1. the **measurement cache** — a JSON file (``~/.cache/kan_sas/
   autotune.json`` by default, override with ``$KAN_SAS_AUTOTUNE_CACHE``)
   holding winners recorded by :func:`autotune`;
2. the **in-repo defaults table** — shapes we have measured on real
   hardware (currently the MXU-aligned TPU defaults);
3. a **shape heuristic** — clamp MXU-friendly tiles to the problem size so
   small problems don't pay for padding to 128.

:func:`autotune` times every candidate from :func:`candidate_tiles` with
the real kernel (interpret mode on CPU, compiled on TPU), records the
winner under the problem key, and returns a report row that
``benchmarks/kan_paths.py`` embeds in ``BENCH_kan_paths.json`` so the tile
choices are visible in the perf trajectory.
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax

Tiles = tuple[int, int, int]

CACHE_ENV = "KAN_SAS_AUTOTUNE_CACHE"

# Sublane granularity per dtype (TPU tiling constraint: second-to-last dim).
_SUBLANE = {"float32": 8, "bfloat16": 16, "int8": 32, "int32": 8}

# Shapes measured on hardware: (kernel, backend) -> tiles.  The TPU entry is
# the MXU-native tiling (128-wide output lanes, bk*M ≈ 128 contraction for
# the default G=5/P=3 grid).
DEFAULTS: dict[tuple[str, str], Tiles] = {
    ("fused", "tpu"): (128, 128, 16),
    ("int8", "tpu"): (128, 128, 16),
    ("fused", "cpu"): (64, 64, 8),
    ("int8", "cpu"): (64, 64, 8),
}


def cache_path() -> str:
    env = os.environ.get(CACHE_ENV)
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "kan_sas", "autotune.json"
    )


# (path, mtime_ns) -> parsed cache; avoids a JSON parse per kernel call.
_mem_cache: dict[tuple[str, int], dict] = {}


def _load_cache() -> dict:
    path = cache_path()
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError:
        return {}
    key = (path, mtime)
    if key not in _mem_cache:
        try:
            with open(path) as f:
                _mem_cache.clear()     # at most one live entry
                _mem_cache[key] = json.load(f)
        except (OSError, ValueError):
            return {}
    return _mem_cache[key]


def _save_cache(cache: dict) -> None:
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(cache, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass  # read-only FS: autotuning still works, it just doesn't persist


def problem_key(
    kernel: str, BS: int, K: int, N: int, M: int, dtype, backend: str
) -> str:
    return f"{kernel}|BS={BS}|K={K}|N={N}|M={M}|dtype={jax.numpy.dtype(dtype).name}|backend={backend}"


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _heuristic(
    kernel: str, BS: int, K: int, N: int, M: int, dtype, backend: str
) -> Tiles:
    """MXU-friendly tiles clamped to the problem (padding-aware)."""
    sub = _SUBLANE.get(jax.numpy.dtype(dtype).name, 8)
    bb = min(128, _round_up(BS, sub))
    bn = min(128, _round_up(N, 128 if backend == "tpu" else 32))
    # contraction width bk*M near 128-512 keeps the MXU busy without
    # blowing VMEM; clamp to K so tiny layers use one step.
    bk = max(1, min(K, max(1, 256 // M)))
    return bb, bn, bk


def candidate_tiles(
    BS: int, K: int, N: int, M: int, dtype=jax.numpy.float32,
    backend: str | None = None,
) -> list[Tiles]:
    """Deduplicated candidate (bb, bn, bk) tilings for one problem."""
    backend = backend or jax.default_backend()
    sub = _SUBLANE.get(jax.numpy.dtype(dtype).name, 8)
    bbs = sorted({min(b, _round_up(BS, sub)) for b in (32, 64, 128, 256)})
    bns = sorted({min(b, _round_up(N, 8)) for b in (64, 128, 256)})
    bks = sorted({min(b, K) for b in (4, 8, 16, 32) if b * M <= 1024})
    out: list[Tiles] = []
    for bb in bbs:
        for bn in bns:
            for bk in bks:
                if (bb, bn, bk) not in out:
                    out.append((bb, bn, bk))
    return out


def get_tiles(
    kernel: str, BS: int, K: int, N: int, M: int,
    dtype=jax.numpy.float32, backend: str | None = None,
) -> Tiles:
    """Resolve tiles: measurement cache -> defaults table -> heuristic."""
    backend = backend or jax.default_backend()
    key = problem_key(kernel, BS, K, N, M, dtype, backend)
    hit = _load_cache().get(key)
    if hit:
        return tuple(hit["tiles"])  # type: ignore[return-value]
    if min(BS, N) >= 128 and (kernel, backend) in DEFAULTS:
        return DEFAULTS[(kernel, backend)]
    return _heuristic(kernel, BS, K, N, M, dtype, backend)


def _time_call(fn: Callable[[], jax.Array], iters: int) -> float:
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def autotune(
    kernel: str,
    run: Callable[[int, int, int], jax.Array],
    BS: int, K: int, N: int, M: int,
    dtype=jax.numpy.float32,
    backend: str | None = None,
    iters: int = 3,
    candidates: list[Tiles] | None = None,
) -> dict:
    """Time every candidate tiling of ``run(bb, bn, bk)``, cache the winner.

    Returns ``{"key", "tiles", "us", "candidates": {tiles_str: us}}`` —
    the report row the benchmark JSON embeds.
    """
    backend = backend or jax.default_backend()
    key = problem_key(kernel, BS, K, N, M, dtype, backend)
    cands = candidates or candidate_tiles(BS, K, N, M, dtype, backend)
    timings: dict[str, float] = {}
    best: Tiles | None = None
    best_us = float("inf")
    for tiles in cands:
        try:
            us = _time_call(lambda: run(*tiles), iters)
        except Exception:
            continue  # illegal tiling for this backend: skip
        timings["x".join(map(str, tiles))] = round(us, 1)
        if us < best_us:
            best, best_us = tiles, us
    if best is None:
        best = get_tiles(kernel, BS, K, N, M, dtype, backend)
        best_us = float("nan")
    cache = _load_cache()
    cache[key] = {"tiles": list(best), "us": round(best_us, 1)}
    _save_cache(cache)
    return {"key": key, "tiles": best, "us": best_us, "candidates": timings}
