"""Tile autotuner for the KAN Pallas kernels (DESIGN.md §2).

The fused kernels tile the ``(BS, N, K)`` iteration space with
``(bb, bn, bk)`` blocks; the best tiling depends on the problem shape, the
dtype (sublane granularity), the backend — and the *kernel*: the dense-band
kernels (``fused``/``int8``) contract ``bk·M`` wide, the sparse N:M kernels
(``sparse``/``sparse_int8``) only ``bk·(P+1)`` wide, so their legal/useful
``bk`` range is ``M/(P+1)×`` larger under the same contraction-width budget.
Rather than hard-coding ``128/128/16`` everywhere, :func:`get_tiles`
resolves tiles in three steps:

1. the **measurement cache** — a JSON file (``~/.cache/kan_sas/
   autotune.json`` by default, override with ``$KAN_SAS_AUTOTUNE_CACHE``)
   holding winners recorded by :func:`autotune`;
2. the **in-repo defaults table** — per-kernel shapes we have measured
   (MXU-aligned TPU tiles for the dense-band kernels, decode-shaped tiles
   for the sparse kernels);
3. a **per-kernel shape heuristic** — clamp friendly tiles to the problem
   size so small problems don't pay for padding to 128.

:func:`autotune` times every candidate from :func:`candidate_tiles` with
the real kernel (interpret mode on CPU, compiled on TPU), records the
winner under the problem key, and returns a report row that
``benchmarks/kan_paths.py`` embeds in ``BENCH_kan_paths.json`` so the tile
choices are visible in the perf trajectory.

Cache robustness: the JSON is written atomically (unique temp file +
``os.replace``) so concurrent processes — pytest-xdist, two engines warming
up — can race without corrupting it; readers get *copies* of the memoised
cache (mutating a result cannot poison later reads); a corrupt or
wrong-schema cache file silently falls back to defaults.
"""

from __future__ import annotations

import copy
import json
import os
import tempfile
import time
from typing import Callable

import jax

Tiles = tuple[int, int, int]

CACHE_ENV = "KAN_SAS_AUTOTUNE_CACHE"

# Sublane granularity per dtype (TPU tiling constraint: second-to-last dim).
_SUBLANE = {"float32": 8, "bfloat16": 16, "int8": 32, "int32": 8}

# Contraction-width budget per grid step (dense-band kernels contract
# bk·M wide, sparse kernels bk·nnz wide; both are capped by the same
# budget, which is what gives the sparse kernels their wider bk range).
_MAX_CONTRACT = 1024

# Shapes measured on hardware / this container: (kernel, backend) -> tiles.
# The TPU dense-band entry is the MXU-native tiling (128-wide output lanes,
# bk*M ≈ 128 contraction for the default G=5/P=3 grid).  The sparse entries
# are decode-shaped: tiny batch tile, bk as wide as the contraction budget
# allows (the sparse contraction is only bk·(P+1) wide).
DEFAULTS: dict[tuple[str, str], Tiles] = {
    ("fused", "tpu"): (128, 128, 16),
    ("int8", "tpu"): (128, 128, 16),
    ("fused", "cpu"): (64, 64, 8),
    ("int8", "cpu"): (64, 64, 8),
    ("sparse", "tpu"): (8, 128, 128),
    ("sparse_int8", "tpu"): (8, 128, 128),
    ("sparse", "cpu"): (8, 256, 256),
    ("sparse_int8", "cpu"): (8, 256, 256),
}


def is_sparse_kernel(kernel: str) -> bool:
    return kernel.startswith("sparse")


def cache_path() -> str:
    env = os.environ.get(CACHE_ENV)
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "kan_sas", "autotune.json"
    )


# (path, mtime_ns) -> parsed cache; avoids a JSON parse per kernel call.
_mem_cache: dict[tuple[str, int], dict] = {}


def _load_cache() -> dict:
    """Parsed cache contents; always a fresh copy (callers may mutate)."""
    path = cache_path()
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError:
        return {}
    key = (path, mtime)
    if key not in _mem_cache:
        try:
            with open(path) as f:
                parsed = json.load(f)
        except (OSError, ValueError):
            return {}  # unreadable / corrupt (e.g. torn write): use defaults
        if not isinstance(parsed, dict):
            return {}
        _mem_cache.clear()     # at most one live entry
        _mem_cache[key] = parsed
    return copy.deepcopy(_mem_cache[key])


def _save_cache(cache: dict) -> None:
    """Atomic write: unique temp file in the target dir + ``os.replace``.

    A fixed temp name would let two concurrent writers interleave into the
    same file; ``mkstemp`` gives each writer its own, and ``os.replace`` is
    atomic on POSIX, so readers only ever see a complete JSON document.
    """
    path = cache_path()
    try:
        d = os.path.dirname(path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".autotune-", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(cache, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
    except OSError:
        pass  # read-only FS: autotuning still works, it just doesn't persist


def problem_key(
    kernel: str, BS: int, K: int, N: int, M: int, dtype, backend: str
) -> str:
    return f"{kernel}|BS={BS}|K={K}|N={N}|M={M}|dtype={jax.numpy.dtype(dtype).name}|backend={backend}"


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _contract_unit(kernel: str, M: int, nnz: int | None) -> int:
    """Per-bk contraction width: M for the dense-band kernels, nnz = P+1
    for the sparse kernels (default M//2 when the caller can't supply it)."""
    if is_sparse_kernel(kernel):
        return max(1, nnz if nnz is not None else M // 2)
    return M


def _heuristic(
    kernel: str, BS: int, K: int, N: int, M: int, dtype, backend: str,
    nnz: int | None = None,
) -> Tiles:
    """Per-kernel friendly tiles clamped to the problem (padding-aware)."""
    sub = _SUBLANE.get(jax.numpy.dtype(dtype).name, 8)
    unit = _contract_unit(kernel, M, nnz)
    if is_sparse_kernel(kernel):
        # Decode-shaped: small batch tile; bk as wide as the contraction
        # budget allows (the narrow bk·(P+1) contraction is the whole point).
        bb = min(32, _round_up(BS, sub))
        bn = min(256, _round_up(N, 128 if backend == "tpu" else 32))
        bk = max(1, min(K, _MAX_CONTRACT // unit))
        return bb, bn, bk
    bb = min(128, _round_up(BS, sub))
    bn = min(128, _round_up(N, 128 if backend == "tpu" else 32))
    # contraction width bk*M near 128-512 keeps the MXU busy without
    # blowing VMEM; clamp to K so tiny layers use one step.
    bk = max(1, min(K, max(1, 256 // M)))
    return bb, bn, bk


def candidate_tiles(
    kernel: str, BS: int, K: int, N: int, M: int, dtype=jax.numpy.float32,
    backend: str | None = None, nnz: int | None = None,
) -> list[Tiles]:
    """Deduplicated candidate (bb, bn, bk) tilings for one problem.

    The ``bk`` range is capped by the contraction-width budget
    (``bk·M <= 1024`` dense-band, ``bk·(P+1) <= 1024`` sparse) — the same
    rule for every kernel, which is what lets the sparse kernels trade
    their narrower contraction for fewer, wider grid steps.
    """
    backend = backend or jax.default_backend()
    sub = _SUBLANE.get(jax.numpy.dtype(dtype).name, 8)
    lane = 128 if backend == "tpu" else 8
    unit = _contract_unit(kernel, M, nnz)
    # Every emitted bb/bn is dtype-sublane / lane aligned (rounding the
    # friendly sizes UP before clamping): a bb=8 sparse candidate under
    # bf16's 16-sublane granularity, or a bn=64 TPU candidate, is a config
    # Mosaic would reject — the kernel-config lint (KL202) now enforces
    # that no such candidate can be emitted.
    if is_sparse_kernel(kernel):
        bbs = sorted({min(_round_up(b, sub), _round_up(BS, sub))
                      for b in (8, 16, 32)})
        bns = sorted({min(_round_up(b, lane), _round_up(N, lane))
                      for b in (64, 128, 256)})
        bk_opts = (16, 32, 64, 128, 256)
    else:
        bbs = sorted({min(_round_up(b, sub), _round_up(BS, sub))
                      for b in (32, 64, 128, 256)})
        bns = sorted({min(_round_up(b, lane), _round_up(N, lane))
                      for b in (64, 128, 256)})
        bk_opts = (4, 8, 16, 32, 64, 128)
    bks = sorted({min(b, K) for b in bk_opts if b * unit <= _MAX_CONTRACT})
    out: list[Tiles] = []
    for bb in bbs:
        for bn in bns:
            for bk in bks:
                if (bb, bn, bk) not in out:
                    out.append((bb, bn, bk))
    return out


def _valid_tiles(hit) -> Tiles | None:
    """Schema-check one cache entry; malformed entries fall through to the
    defaults instead of raising."""
    if not isinstance(hit, dict):
        return None
    tiles = hit.get("tiles")
    if (
        isinstance(tiles, (list, tuple))
        and len(tiles) == 3
        and all(isinstance(t, int) and t > 0 for t in tiles)
    ):
        return tuple(tiles)  # type: ignore[return-value]
    return None


def clamp_default(
    kernel: str, backend: str, BS: int, K: int, N: int, dtype
) -> Tiles:
    """The DEFAULTS entry as resolved for one problem: clamped so small-K
    (or N just over the gate) shapes don't pay large padding multiples,
    then ``bb`` re-rounded UP to the dtype's sublane granularity — the
    decode-shaped sparse default (bb=8) is only aligned under fp32; under
    bf16/int8 the clamp itself must restore alignment.  This is the ONE
    definition both ``get_tiles`` and the kernel-config lint validate."""
    sub = _SUBLANE.get(jax.numpy.dtype(dtype).name, 8)
    bb, bn, bk = DEFAULTS[(kernel, backend)]
    return (
        _round_up(min(bb, _round_up(BS, sub)), sub),
        min(bn, _round_up(N, 8)),
        min(bk, K),
    )


def get_tiles(
    kernel: str, BS: int, K: int, N: int, M: int,
    dtype=jax.numpy.float32, backend: str | None = None,
    nnz: int | None = None,
) -> Tiles:
    """Resolve tiles: measurement cache -> defaults table -> heuristic."""
    backend = backend or jax.default_backend()
    key = problem_key(kernel, BS, K, N, M, dtype, backend)
    hit = _valid_tiles(_load_cache().get(key))
    if hit:
        return hit
    if (kernel, backend) in DEFAULTS:
        use = (
            BS <= 32 and N >= 128          # sparse defaults: decode-shaped,
            if is_sparse_kernel(kernel)    # apply in the regime measured
            else min(BS, N) >= 128
        )
        if use:
            return clamp_default(kernel, backend, BS, K, N, dtype)
    return _heuristic(kernel, BS, K, N, M, dtype, backend, nnz)


def _time_call(fn: Callable[[], jax.Array], iters: int) -> float:
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def record_winner(
    kernel: str, BS: int, K: int, N: int, M: int, dtype, backend: str,
    tiles: Tiles, us: float,
) -> str:
    """Write one measured winner into the cache (atomic, see _save_cache).

    For callers that time candidates themselves (e.g. the benchmark's
    interleaved fused-vs-sparse sweep) but still want ``get_tiles`` to hand
    the winner to every later ``ops.py`` call.  Returns the problem key.
    """
    key = problem_key(kernel, BS, K, N, M, dtype, backend)
    cache = _load_cache()
    cache[key] = {"tiles": list(tiles), "us": round(float(us), 1)}
    _save_cache(cache)
    return key


def autotune(
    kernel: str,
    run: Callable[[int, int, int], jax.Array],
    BS: int, K: int, N: int, M: int,
    dtype=jax.numpy.float32,
    backend: str | None = None,
    iters: int = 3,
    candidates: list[Tiles] | None = None,
    nnz: int | None = None,
) -> dict:
    """Time every candidate tiling of ``run(bb, bn, bk)``, cache the winner.

    Returns ``{"key", "tiles", "us", "candidates": {tiles_str: us}}`` —
    the report row the benchmark JSON embeds.
    """
    backend = backend or jax.default_backend()
    key = problem_key(kernel, BS, K, N, M, dtype, backend)
    cands = candidates or candidate_tiles(kernel, BS, K, N, M, dtype, backend, nnz)
    timings: dict[str, float] = {}
    best: Tiles | None = None
    best_us = float("inf")
    for tiles in cands:
        try:
            us = _time_call(lambda: run(*tiles), iters)
        except Exception:
            continue  # illegal tiling for this backend: skip
        timings["x".join(map(str, tiles))] = round(us, 1)
        if us < best_us:
            best, best_us = tiles, us
    if best is None:
        best = get_tiles(kernel, BS, K, N, M, dtype, backend, nnz)
        best_us = float("nan")
    cache = _load_cache()
    cache[key] = {"tiles": list(best), "us": round(best_us, 1)}
    _save_cache(cache)
    return {"key": key, "tiles": best, "us": best_us, "candidates": timings}
