"""Pallas TPU kernel: block-table gather for the paged KV cache.

The paged cache stores KV in a *pool* of fixed-size blocks
``pool : (n_blocks, block_size, ...)`` shared by every sequence; each batch
row owns a *block table* ``table : (B, n_logical)`` of physical block ids
(``n_logical·block_size == max_seq``).  The paged attention read path
(DESIGN.md §3b) first materialises the logical contiguous view

``view[b, l·bs + o, ...] = pool[table[b, l], o, ...]``

and then runs the *unchanged* dense attention math on it — which is what
makes paged serving bit-identical to the dense contiguous cache: the gather
is pure data movement, and positions beyond a row's coverage land on
physical block 0 (the reserved sentinel/trash block) whose finite garbage
is annihilated by the causal mask (``exp(NEG_INF - m) == 0.0`` exactly).

On TPU the gather is one ``pallas_call`` over a ``(B, n_logical)`` grid:
the block table rides in scalar-prefetch memory (SMEM) so each grid step's
input DMA address — ``pool[table[b, l]]`` — is computed *before* the body
runs (``pltpu.PrefetchScalarGridSpec``), i.e. the kernel is a pure
table-driven DMA pipeline with no compute.  Off TPU (and under
``interpret=True`` for tests) the same semantics come from ``jnp.take``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def gather_blocks_reference(pool: jax.Array, table: jax.Array) -> jax.Array:
    """``jnp.take`` fallback: (n_blocks, bs, ...) x (B, L) -> (B, L·bs, ...).

    ``mode="clip"`` (jnp.take's default under jit) keeps out-of-range ids
    safe; the engine never emits them (tables are sentinel-filled).
    """
    B, L = table.shape
    bs = pool.shape[1]
    g = jnp.take(pool, table.reshape(-1), axis=0)      # (B·L, bs, ...)
    return g.reshape((B, L * bs) + pool.shape[2:])


def _gather_kernel(tbl_ref, pool_ref, out_ref):
    # pool_ref: one (1, bs, ...) physical block, DMA'd per the index map;
    # out_ref: the matching (1, 1, bs, ...) logical slot of the output.
    out_ref[0] = pool_ref[...]


def gather_blocks_pallas(
    pool: jax.Array, table: jax.Array, interpret: bool = False
) -> jax.Array:
    """Block-table gather as one TPU ``pallas_call`` (see module docstring).

    Bit-identical to :func:`gather_blocks_reference` (tested in
    ``tests/test_kv_pool.py`` via interpret mode): both produce
    ``pool[table[b, l]]`` with no arithmetic on the values.
    """
    B, L = table.shape
    bs = pool.shape[1]
    rest = pool.shape[2:]
    zeros = (0,) * len(rest)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, L),
        in_specs=[
            pl.BlockSpec(
                (1, bs) + rest,
                lambda b, l, tbl: (tbl[b, l], 0) + zeros,
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bs) + rest,
            lambda b, l, tbl: (b, l, 0) + zeros,
        ),
    )
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, L, bs) + rest, pool.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), pool)
    return out.reshape((B, L * bs) + rest)


def gather_blocks(
    pool: jax.Array, table: jax.Array, method: str = "auto"
) -> jax.Array:
    """Dispatch: the Pallas DMA-pipeline kernel on TPU, ``jnp.take``
    elsewhere (``method`` pins a path for tests: ``take`` | ``pallas`` |
    ``interpret``).  Inside an outer jit the branches trace directly."""
    if method == "auto":
        method = "pallas" if jax.default_backend() == "tpu" else "take"
    if method == "pallas":
        return gather_blocks_pallas(pool, table, interpret=False)
    if method == "interpret":
        return gather_blocks_pallas(pool, table, interpret=True)
    if method == "take":
        return gather_blocks_reference(pool, table)
    raise ValueError(method)
