"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True``; on a
real TPU backend they compile to Mosaic. The switch is automatic.

Tile sizes default to ``None`` = "ask the autotuner": the measurement cache
(``kernels/autotune.py``) is consulted per problem shape, falling back to
the in-repo defaults table and a padding-aware heuristic.  Explicit
``bb/bn/bk`` always win (the kernel unit tests pin them).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bspline import SplineGrid
from repro.kernels import autotune as _tune
from repro.kernels import bspline_lut as _lut
from repro.kernels import kan_fused_gemm as _fused
from repro.kernels import kan_int8_gemm as _int8
from repro.kernels import kan_sparse_gemm as _sparse


# Registered Pallas kernels, for the kernel-config lint
# (``repro.analysis.kernel_configs``): the dtypes each kernel serves, a
# representative basis count M (and nnz = P+1 for the sparse datapath,
# default G=5/P=3 grid), whether the kernel fuses a base term (an extra
# (bk, bn) VMEM block per grid step), and the output element size when it
# differs from the input dtype (the int8 kernels accumulate int32 and emit
# fp32 from the fused dequant epilogue).  Adding a kernel without
# registering it here fails the lint CLI's coverage check.
KERNELS: dict[str, dict] = {
    "fused": {"M": 8, "dtypes": ("float32", "bfloat16"), "base": True},
    "int8": {"M": 8, "dtypes": ("int8",), "base": False, "out_bytes": 4},
    "sparse": {
        "M": 8, "nnz": 4, "dtypes": ("float32", "bfloat16"), "base": True,
    },
    "sparse_int8": {
        "M": 8, "nnz": 4, "dtypes": ("int8",), "base": False, "out_bytes": 4,
    },
}


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _resolve_tiles(kernel, BS, K, N, M, dtype, bb, bn, bk, nnz=None):
    if bb is None or bn is None or bk is None:
        tb, tn, tk = _tune.get_tiles(kernel, BS, K, N, M, dtype, nnz=nnz)
        bb, bn, bk = bb or tb, bn or tn, bk or tk
    return bb, bn, bk


def bspline_lut(
    x: jax.Array, lut: jax.Array, grid: SplineGrid, block: int = 1024,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Tabulated B-spline unit over a flat input vector -> (vals, k)."""
    if interpret is None:
        interpret = _interpret_default()
    return _lut.bspline_lut_pallas(x, lut, grid, block=block, interpret=interpret)


def kan_fused_gemm(
    x: jax.Array, coeff: jax.Array, grid: SplineGrid,
    base_w: jax.Array | None = None,
    bb: int | None = None, bn: int | None = None, bk: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused KAN layer (Eq. 1): spline term + optional base term in ONE
    ``pallas_call`` — no separate base GEMM, no second HBM read of ``x``.

    Accepts ``x`` of shape ``(..., K)``; leading dims are flattened.
    """
    if interpret is None:
        interpret = _interpret_default()
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    BS, K = x2.shape
    N, M = coeff.shape[-1], grid.n_basis
    bb, bn, bk = _resolve_tiles("fused", BS, K, N, M, x.dtype, bb, bn, bk)
    y = _fused.kan_fused_gemm_pallas(
        x2, coeff, grid, base_w=base_w, bb=bb, bn=bn, bk=bk,
        interpret=interpret,
    )
    return y.reshape(lead + (coeff.shape[-1],))


def kan_sparse_gemm(
    x: jax.Array, coeff: jax.Array, grid: SplineGrid,
    base_w: jax.Array | None = None,
    bb: int | None = None, bn: int | None = None, bk: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Compact N:M sparse KAN layer (paper §IV-A): each input contracts only
    its ``P+1`` non-zero basis values against a gathered ``(P+1, N)``
    coefficient slab — ``(G+P)/(P+1)×`` fewer MACs and coefficient reads
    than the dense-band fused kernel.  Spline + optional base term in ONE
    ``pallas_call``; the decode/small-batch serving path (DESIGN.md §2a).

    Accepts ``x`` of shape ``(..., K)``; leading dims are flattened.
    """
    if interpret is None:
        interpret = _interpret_default()
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    BS, K = x2.shape
    N, M = coeff.shape[-1], grid.n_basis
    bb, bn, bk = _resolve_tiles(
        "sparse", BS, K, N, M, x.dtype, bb, bn, bk, nnz=grid.n_nonzero
    )
    y = _sparse.kan_sparse_gemm_pallas(
        x2, coeff, grid, base_w=base_w, bb=bb, bn=bn, bk=bk,
        interpret=interpret,
    )
    return y.reshape(lead + (coeff.shape[-1],))


def kan_sparse_int8_gemm(
    x_q: jax.Array, lut_u8: jax.Array, coeff_q: jax.Array, grid: SplineGrid,
    scale: jax.Array | None = None,
    bb: int | None = None, bn: int | None = None, bk: int | None = None,
    qmax: int = 255,
    lut_scale: int | None = None,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
) -> jax.Array:
    """Integer sparse KAN GEMM — same contract as :func:`kan_int8_gemm`
    (bit-identical accumulator, same fused dequant epilogue), but the N:M
    sparse datapath: gathered int8 coefficient slabs instead of the dense
    band.  The int8 decode/small-batch path.
    """
    if interpret is None:
        interpret = _interpret_default()
    if lut_scale is None:
        lut_scale = _int8.resolve_lut_scale(lut_u8, grid, lut_u8.shape[0])
    lead = x_q.shape[:-1]
    x2 = x_q.reshape(-1, x_q.shape[-1])
    BS, K = x2.shape
    N, M = coeff_q.shape[-1], grid.n_basis
    bb, bn, bk = _resolve_tiles(
        "sparse_int8", BS, K, N, M, jnp.int8, bb, bn, bk, nnz=grid.n_nonzero
    )
    y = _sparse.kan_sparse_int8_gemm_pallas(
        x2, coeff_q, grid, scale=scale, bb=bb, bn=bn, bk=bk, qmax=qmax,
        S=lut_u8.shape[0], lut_scale=lut_scale,
        out_dtype=out_dtype, interpret=interpret,
    )
    return y.reshape(lead + (coeff_q.shape[-1],))


def kan_int8_gemm(
    x_q: jax.Array, lut_u8: jax.Array, coeff_q: jax.Array, grid: SplineGrid,
    scale: jax.Array | None = None,
    bb: int | None = None, bn: int | None = None, bk: int | None = None,
    qmax: int = 255,
    lut_scale: int | None = None,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
) -> jax.Array:
    """Integer-only fused KAN GEMM.

    ``lut_u8`` fixes the table resolution ``S``; the kernel regenerates the
    ROM in-register (see ``kan_int8_gemm.py``), so its *value scale* must be
    known: pass ``lut_scale`` explicitly (e.g. ``QuantizedGrid.lut_scale``),
    or leave it ``None`` to infer-and-verify from a concrete table (a traced
    table then assumes the default power-of-two scale).  With ``scale=None``
    returns the raw int32 accumulator; with a per-channel ``scale: (N,)``
    the dequant multiply is fused into the kernel epilogue and the result is
    ``out_dtype``.
    """
    if interpret is None:
        interpret = _interpret_default()
    if lut_scale is None:
        lut_scale = _int8.resolve_lut_scale(lut_u8, grid, lut_u8.shape[0])
    lead = x_q.shape[:-1]
    x2 = x_q.reshape(-1, x_q.shape[-1])
    BS, K = x2.shape
    N, M = coeff_q.shape[-1], grid.n_basis
    bb, bn, bk = _resolve_tiles("int8", BS, K, N, M, jnp.int8, bb, bn, bk)
    y = _int8.kan_int8_gemm_pallas(
        x2, coeff_q, grid, scale=scale, bb=bb, bn=bn, bk=bk, qmax=qmax,
        S=lut_u8.shape[0], lut_scale=lut_scale,
        out_dtype=out_dtype, interpret=interpret,
    )
    return y.reshape(lead + (coeff_q.shape[-1],))
