"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True``; on a
real TPU backend they compile to Mosaic. The switch is automatic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bspline import SplineGrid
from repro.kernels import bspline_lut as _lut
from repro.kernels import kan_fused_gemm as _fused
from repro.kernels import kan_int8_gemm as _int8


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def bspline_lut(
    x: jax.Array, lut: jax.Array, grid: SplineGrid, block: int = 1024,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Tabulated B-spline unit over a flat input vector -> (vals, k)."""
    if interpret is None:
        interpret = _interpret_default()
    return _lut.bspline_lut_pallas(x, lut, grid, block=block, interpret=interpret)


def kan_fused_gemm(
    x: jax.Array, coeff: jax.Array, grid: SplineGrid,
    bb: int = 128, bn: int = 128, bk: int = 16,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused on-the-fly-B KAN GEMM (spline term of Eq. 1).

    Accepts ``x`` of shape ``(..., K)``; leading dims are flattened.
    """
    if interpret is None:
        interpret = _interpret_default()
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _fused.kan_fused_gemm_pallas(
        x2, coeff, grid, bb=bb, bn=bn, bk=bk, interpret=interpret
    )
    return y.reshape(lead + (coeff.shape[-1],))


def kan_int8_gemm(
    x_q: jax.Array, lut_u8: jax.Array, coeff_q: jax.Array, grid: SplineGrid,
    bb: int = 128, bn: int = 128, bk: int = 16, qmax: int = 255,
    interpret: bool | None = None,
) -> jax.Array:
    """Integer-only fused KAN GEMM -> int32 accumulator."""
    if interpret is None:
        interpret = _interpret_default()
    lead = x_q.shape[:-1]
    x2 = x_q.reshape(-1, x_q.shape[-1])
    y = _int8.kan_int8_gemm_pallas(
        x2, lut_u8, coeff_q, grid, bb=bb, bn=bn, bk=bk, qmax=qmax,
        interpret=interpret,
    )
    return y.reshape(lead + (coeff_q.shape[-1],))
