"""In-kernel building blocks shared by the KAN Pallas kernels.

Both the floating-point (`kan_fused_gemm`) and integer (`kan_int8_gemm`)
datapaths need the same two pieces of the paper's architecture rendered as
branch-free vector code:

* the B-spline unit (§III-A): evaluate the ``P+1`` non-zero cardinal
  B-spline values for a tile of inputs entirely in VMEM/registers
  (:func:`compact_basis_inblock`, :func:`cardinal_values_inblock`);
* the M-to-N multiplexer run in reverse (§IV-B): place those compact values
  into the dense ``M = G+P`` band of an MXU tile with compare-selects — no
  gathers, no scatters (:func:`band_scatter`);
* the M-to-N multiplexer run *forward* (§IV-B, the N:M vector PE of the
  sparse kernels): gather, per input, the ``(P+1, N)`` coefficient slab its
  non-zero basis values touch (:func:`gather_coeff_slabs`);
* the integer Align/Compare units + ROM-free table fetch of the int8
  datapath (Eq. 5), shared by the dense-band and sparse int8 kernels
  (:func:`int8_compact_values_inblock`).

Everything here lowers inside a TPU kernel with iota / where / arithmetic,
except :func:`gather_coeff_slabs`, which is a VMEM gather (plain XLA ops in
interpret mode; requires Mosaic dynamic-gather support when compiled).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

from repro.core.bspline import SplineGrid

# jax renamed TPUCompilerParams -> CompilerParams across versions.
CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)


def cardinal_values_inblock(xa: jax.Array, P: int) -> jax.Array:
    """Cardinal B-spline values ``B_{0,P}(xa + (P - i))`` for ``i = 0..P``.

    ``xa`` is the in-interval offset in ``[0, 1)``; the result has shape
    ``xa.shape + (P+1,)`` ordered by ascending basis index.  Runs the
    Cox-de Boor triangle on a ``(P+2)``-wide band: since
    ``u_i = xa + (P-i)`` lies in ``[P-i, P-i+1)``, the degree-0 coefficient
    vector for point ``i`` is the indicator ``e_{P-i}``.
    """
    dtype = xa.dtype
    offs = dtype.type(P) - jax.lax.broadcasted_iota(
        jnp.int32, xa.shape + (P + 1,), xa.ndim
    ).astype(dtype)
    u = xa[..., None] + offs                                    # (..., P+1)
    nseg = P + 2
    seg = jax.lax.broadcasted_iota(jnp.int32, u.shape + (nseg - 1,), u.ndim)
    b = jnp.where(
        (u[..., None] >= seg.astype(dtype)) & (u[..., None] < (seg + 1).astype(dtype)),
        dtype.type(1.0),
        dtype.type(0.0),
    )                                                           # (..., P+1, P+1)
    for p in range(1, P + 1):
        idx = jax.lax.broadcasted_iota(
            jnp.int32, u.shape + (nseg - 1 - p,), u.ndim
        ).astype(dtype)
        left = (u[..., None] - idx) / dtype.type(p) * b[..., :-1]
        right = (idx + dtype.type(p + 1) - u[..., None]) / dtype.type(p) * b[..., 1:]
        b = left + right
    return b[..., 0]


def compact_basis_inblock(
    x: jax.Array, grid: SplineGrid
) -> tuple[jax.Array, jax.Array]:
    """Exact compact N:M evaluation as branch-free vector code.

    Returns ``vals: x.shape + (P+1,)`` (ascending basis index) and the
    interval index ``k``.  Identical math to
    :func:`repro.core.bspline.compact_basis`; written to lower cleanly
    inside a TPU kernel.  Evaluation runs in float32 regardless of
    ``x.dtype`` (the Cox-de Boor triangle loses too much in bf16); callers
    cast the resulting band to the MXU input dtype.
    """
    P = grid.P
    xf = x.astype(jnp.float32)
    z = (xf - jnp.float32(grid.t0)) / jnp.float32(grid.delta)
    k = jnp.clip(jnp.floor(z).astype(jnp.int32), P, grid.n_basis - 1)
    xa = jnp.clip(z - k.astype(jnp.float32), 0.0, 1.0)
    return cardinal_values_inblock(xa, P), k


def band_scatter(vals: jax.Array, k: jax.Array, M: int) -> jax.Array:
    """The M-to-N multiplexer in reverse (paper §IV-B).

    Places compact values ``vals: (..., P+1)`` (ascending basis index, the
    window ``B_{k-P} .. B_k``) into the dense band ``(..., M)`` with
    compare-selects — structured N:M sparsity becomes an MXU-aligned dense
    tile without gathers.  Works for any dtype (float or int).
    """
    P = vals.shape[-1] - 1
    m_iota = jax.lax.broadcasted_iota(jnp.int32, k.shape + (M,), k.ndim)
    rel = m_iota - (k[..., None] - P)                 # (..., M)
    zero = jnp.zeros((), vals.dtype)
    band = jnp.zeros(k.shape + (M,), vals.dtype)
    for i in range(P + 1):
        band = band + jnp.where(rel == i, vals[..., i][..., None], zero)
    return band


def gather_coeff_slabs(c: jax.Array, k: jax.Array, P: int) -> jax.Array:
    """The M-to-N multiplexer run *forward* (paper §IV-B, the N:M vector PE).

    ``c: (bk, M, bn)`` coefficient block, ``k: (bb, bk)`` interval indices in
    ``[P, M-1]`` -> ``(bb, bk, P+1, bn)``: per input, the coefficient slab
    ``C[j, k-P .. k, :]`` its ``P+1`` non-zero basis values touch (ascending
    basis index, matching :func:`cardinal_values_inblock`).  This is the
    select-by-``k`` that lets the sparse kernels contract only ``bk·(P+1)``
    wide instead of the dense ``bk·M`` band.

    Lowered as one batched gather; XLA fuses the broadcast into it, so no
    ``(bb, bk, M, bn)`` temporary is materialised.  In interpret mode these
    are plain XLA ops; compiling on TPU needs Mosaic dynamic-gather support
    (the sparse kernels are decode-shape kernels — small ``bb·bk`` — by
    design, see DESIGN.md §2a).
    """
    bb, bk = k.shape
    offs = jax.lax.broadcasted_iota(jnp.int32, k.shape + (P + 1,), k.ndim)
    idx = (k[..., None] - P) + offs                   # (bb, bk, P+1) in [0, M-1]
    cb = jnp.broadcast_to(c[None], (bb,) + c.shape)   # fused into the gather
    return jnp.take_along_axis(cb, idx[..., None], axis=2, mode="clip")


def int8_compact_values_inblock(
    x_q: jax.Array, grid: SplineGrid, S: int, qmax: int, lut_scale: int
) -> tuple[jax.Array, jax.Array]:
    """Integer Align + Compare units (paper Eq. 5) + ROM-free table fetch.

    ``x_q: (...,) int32`` activations quantised over the extended domain ->
    ``(bvals: (..., P+1) int32, k: (...,) int32)``.  The uint8 table entries
    are by construction ``round(B_{0,P}(addr/(S-1) + c) · lut_scale)``, so
    the generating function is evaluated directly with the shared
    compare-select Cox-de Boor code — bit-identical to the direct +
    inverted-address half-table fetch (tested), no O(S) one-hot matmuls.
    Shared by the dense-band (``kan_int8_gemm``) and sparse
    (``kan_sparse_gemm``) integer kernels.
    """
    P, M = grid.P, grid.n_basis
    u = (grid.G + 2 * P) * x_q
    k = jnp.clip(u // qmax, P, M - 1)
    addr = jnp.clip(u - qmax * k, 0, qmax)
    addr = (addr * (S - 1)) // qmax
    xa_q = addr.astype(jnp.float32) / jnp.float32(S - 1)
    vals = cardinal_values_inblock(xa_q, P)           # f32 (..., P+1)
    bvals = jnp.clip(
        jnp.round(vals * jnp.float32(lut_scale)), 0.0, 255.0
    ).astype(jnp.int32)
    return bvals, k
