"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is validated against these references in
``tests/test_kernels_*.py`` across shape/dtype sweeps (interpret mode on CPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bspline
from repro.core.bspline import SplineGrid


def ref_bspline_compact(
    x: jax.Array, grid: SplineGrid, lut: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the B-spline unit: compact (P+1) values + interval index.

    With ``lut`` given, mirrors the tabulated datapath (paper Fig. 5);
    otherwise exact Cox-de Boor.
    """
    if lut is None:
        return bspline.compact_basis(x, grid)
    return bspline.lut_basis_compact(x, grid, lut)


def ref_kan_gemm(x: jax.Array, coeff: jax.Array, grid: SplineGrid) -> jax.Array:
    """Oracle for the fused KAN GEMM: dense-B einsum (the spline term of
    Eq. 1, no base term)."""
    B = bspline.cox_de_boor_dense(x, grid)      # (BS, K, M)
    return jnp.einsum("bkm,kmn->bn", B, coeff)


def ref_kan_gemm_int8(
    x_q: jax.Array,
    coeff_q: jax.Array,
    lut_u8: jax.Array,
    grid: SplineGrid,
    qmax: int = 255,
) -> jax.Array:
    """Oracle for the int8 fused GEMM: integer address math (paper Eq. 5),
    uint8 LUT fetch, int8 coeffs, int32 accumulation. Returns int32."""
    G, P = grid.G, grid.P
    S = lut_u8.shape[0]
    half = lut_u8.shape[1]
    u = (G + 2 * P) * (x_q.astype(jnp.int32) - 0)
    k = jnp.clip(u // qmax, P, grid.n_basis - 1)
    addr = jnp.clip(u - qmax * k, 0, qmax)
    addr = (addr * (S - 1)) // qmax
    addr_inv = (S - 1) - addr
    cols = []
    for i in range(P + 1):
        j = P - i
        cols.append(lut_u8[addr, j] if j < half else lut_u8[addr_inv, P - j])
    bvals = jnp.stack(cols, axis=-1).astype(jnp.int32)      # (BS, K, P+1)
    # dense-band scatter then integer GEMM
    m = jnp.arange(grid.n_basis, dtype=jnp.int32)
    rel = m - (k[..., None] - P)
    inside = (rel >= 0) & (rel <= P)
    dense = jnp.where(
        inside, jnp.take_along_axis(bvals, jnp.clip(rel, 0, P), axis=-1), 0
    )
    return jnp.einsum(
        "bkm,kmn->bn", dense, coeff_q.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
