"""Lint launcher: ``python -m repro.launch.lint [paths...]``.

The launcher-flavoured front door to the kanlint subsystem
(``repro.analysis``): runs the AST lints, the sharding-contract audit, and
the kernel-config validator, prints a per-rule summary, and exits non-zero
on new (non-baselined, non-waived) findings — same contract as
``python -m repro.analysis --check`` that CI runs, plus the summary table.
"""

from __future__ import annotations

import argparse
import sys
from collections import Counter

from repro.analysis import DEFAULT_BASELINE, run_check


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro.launch.lint")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/directories to lint (default: src)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--no-kernel-validator", action="store_true")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    report = run_check(
        args.paths or ["src"], baseline_path=args.baseline,
        kernel_validator=not args.no_kernel_validator,
    )
    new, old = report["new"], report["baselined"]
    for f in new:
        print(f.format())
    by_rule = Counter(f.rule for f in new)
    rules = " ".join(f"{r}={n}" for r, n in sorted(by_rule.items())) or "none"
    print(f"[lint] scanned {report['files']} files: "
          f"{len(new)} new finding(s) ({rules}), {len(old)} baselined")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
