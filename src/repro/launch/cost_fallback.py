"""Analytic cost fallback for cells whose cost-faithful compile is
pathological in the XLA SPMD partitioner (documented in EXPERIMENTS.md
§Roofline): gemma3-12b train_4k (12 unrolled attention blocks) and the
xlstm-1.3b decode cells (hundreds of small recurrent-state ops x 512-way
partitioning). Each artifact is tagged ``"method": "analytic"``.

Formulas are the ones validated against XLA on the cells that DO compile
(fwd FLOPs within 2% on qwen1.5-0.5b; xlstm train memory dominated by the
same state-traffic model XLA confirmed at 59x after chunking).

    PYTHONPATH=src python -m repro.launch.cost_fallback
"""

import json
import os

from repro import configs
from repro.configs.common import SHAPES
from repro.models import costs


def dense_like_train(arch_name: str, shape: str, n_dev=256, tp=16) -> dict:
    arch = configs.get_config(arch_name)
    model = arch.model
    cell = SHAPES[shape]
    tokens_dev = cell.global_batch * cell.seq_len / n_dev
    n_act = costs.n_active_params(model)
    # remat=unit: fwd + recompute + bwd(2x) = 4 passes
    dense_f = 4.0 * 2.0 * n_act * tokens_dev / tp
    # attention: full T^2 chunks (window masking does not skip compute in
    # this implementation), heads sharded by tp
    attn_f = 0.0
    for blocks, mult in ((model.unit, model.n_repeats), (model.prologue, 1),
                         (model.epilogue, 1)):
        for b in blocks:
            if b.attn is not None:
                hd = b.attn.n_heads * b.attn.head_dim
                seqs_dev = cell.global_batch / n_dev * 1  # per accum total
                attn_f += mult * 4.0 * (cell.global_batch / 16) * \
                    cell.seq_len ** 2 * hd / tp * 4.0  # 4 passes w/ remat
    flops = dense_f + attn_f
    bytes_ = costs.analytic_hbm_bytes(
        model, global_batch=cell.global_batch, seq=cell.seq_len,
        mode="train", n_devices=n_dev, tp=tp,
    )
    # activation traffic at layer boundaries (saved + reread + grads)
    d = model.d_model
    bytes_ += model.n_layers * tokens_dev * d * 2 * 6
    # collectives: Megatron-style 4 activation ARs per attn+mlp block per
    # fwd, x3 with bwd+remat, of (tokens_dev x d) bf16 + DP grad all-reduce
    coll = model.n_layers * 4 * 3 * tokens_dev * d * 2
    coll += 2 * costs.n_params(model) * 2 / tp
    return {
        "flops": flops, "bytes_accessed": bytes_,
        "collectives": {"total": coll, "all-reduce": coll},
        "model_flops_global": costs.model_flops(
            model, cell.global_batch * cell.seq_len, "train"),
        "n_active_params": costs.n_active_params(model),
        "method": "analytic",
    }


def xlstm_decode(shape: str, n_dev=256, tp=16) -> dict:
    arch = configs.get_config("xlstm-1.3b")
    model = arch.model
    cell = SHAPES[shape]
    B_dev = max(1, cell.global_batch // 16)
    n = costs.n_params(model)
    flops = 2.0 * n * cell.global_batch / n_dev / 1  # params fwd (tp folds B)
    flops = 2.0 * n / tp * B_dev
    # state update per block: mLSTM (H,D,D) ops
    state_f = 0.0
    state_b = 0.0
    for b in model.unit:
        if b.xlstm is None:
            continue
        H, D = b.xlstm.n_heads, b.xlstm.head_dim
        per = B_dev * H * 6.0 * D * D
        state_f += model.n_repeats / len(model.unit) * 0  # folded below
    # per-rep: 7 mlstm + 1 slstm
    xc = model.unit[0].xlstm
    H, D = xc.n_heads, xc.head_dim
    reps = model.n_repeats
    state_f = reps * (7 * B_dev * H * 6.0 * D * D / tp +
                      B_dev * 4 * H * (model.d_model // H) ** 2 * 2 / tp)
    state_b = reps * 8 * B_dev * H * D * D * 4.0 * 2 / tp
    flops += state_f
    bytes_ = costs.analytic_hbm_bytes(
        model, global_batch=cell.global_batch, seq=cell.seq_len,
        mode="decode", n_devices=n_dev, tp=tp,
    ) + state_b
    coll = model.n_layers * 2 * B_dev * model.d_model * 2  # out-proj ARs
    return {
        "flops": flops, "bytes_accessed": bytes_,
        "collectives": {"total": coll, "all-reduce": coll},
        "model_flops_global": costs.model_flops(model, cell.global_batch, "decode"),
        "n_active_params": costs.n_active_params(model),
        "method": "analytic",
    }


def main():
    art = os.path.join(os.getcwd(), "artifacts", "dryrun")
    cells = [
        ("gemma3-12b", "train_4k", dense_like_train("gemma3-12b", "train_4k")),
        ("xlstm-1.3b", "long_500k", xlstm_decode("long_500k")),
        ("xlstm-1.3b", "decode_32k", xlstm_decode("decode_32k")),
    ]
    for arch, shape, payload in cells:
        cell = SHAPES[shape]
        payload.update({
            "arch": arch, "shape": shape, "mesh": "single", "mode": cell.mode,
        })
        out = os.path.join(art, f"{arch}__{shape}__single__cost.json")
        if os.path.exists(out):
            print("exists, skipping:", out)
            continue
        with open(out, "w") as f:
            json.dump(payload, f, indent=2)
        print("wrote analytic fallback:", out)


if __name__ == "__main__":
    main()
