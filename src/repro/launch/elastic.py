"""Elastic re-meshing (DESIGN.md §4): restore a checkpoint onto a DIFFERENT
mesh than it was written from.

Checkpoints store full logical arrays (checkpoint/store.py), so elasticity
reduces to recomputing shardings for the new mesh from the same logical-axis
tree and device_put-ing each leaf. This is what a 512-chip -> 256-chip
failover (or a scale-up) does at the controller level; the unit test
exercises 1-device -> k-fake-device resharding."""

from __future__ import annotations

import jax

from repro.checkpoint import store
from repro.dist import sharding as SH
from repro.models import lm
from repro.optim import adamw


def restore_elastic(ckpt_dir: str, step: int, model_cfg, new_mesh, pdtype):
    """-> (params, opt_state, manifest) resharded for ``new_mesh``."""
    axes = lm.param_axes(model_cfg)
    abs_params = lm.abstract_params(model_cfg, dtype=pdtype)
    pshard = SH.tree_shardings(axes, abs_params, new_mesh)
    abs_opt = jax.eval_shape(adamw.init_state, abs_params)
    oshard = {
        "m": SH.tree_zero_shardings(axes, abs_params, new_mesh),
        "v": SH.tree_zero_shardings(axes, abs_params, new_mesh),
        "step": jax.sharding.NamedSharding(new_mesh, jax.sharding.PartitionSpec()),
    }
    (params, opt_state), manifest = store.restore(
        ckpt_dir, step, (abs_params, abs_opt), shardings=(pshard, oshard)
    )
    return params, opt_state, manifest
