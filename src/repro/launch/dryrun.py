import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (brief: deliverable (e)).

Lowers + compiles every (architecture x input-shape x mesh) cell against the
production mesh with 512 placeholder host devices, and records:

* ``memory_analysis`` (per-device argument/output/temp/peak bytes — proves
  the cell fits a 16 GB v5e chip),
* ``cost_analysis`` (per-device HLO FLOPs + bytes accessed — §Roofline),
* collective bytes parsed from the optimized HLO (all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute), per op class.

Artifacts land in ``artifacts/dryrun/<arch>__<shape>__<mesh>.json``; the
roofline table (benchmarks/roofline.py) and EXPERIMENTS.md §Dry-run read
them. Usage:

    python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--jobs 3]     # orchestrates subprocesses
"""

import argparse
import json
import re
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs import common as C
from repro.dist import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.optim import adamw
from repro.train import step as train_step_lib

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")
ART_DIR = os.path.abspath(os.path.join(os.getcwd(), "artifacts", "dryrun"))

COLLECTIVE_RE = re.compile(
    r"=\s+(?:\([^)]*\)|(?P<ty>[a-z0-9]+)\[(?P<dims>[0-9,]*)\][^ ]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
TUPLE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "pred": 1, "s64": 8, "u64": 8,
}


def cost_analysis_dict(compiled) -> dict:
    """Normalize Compiled.cost_analysis() across jax versions (list of
    per-program dicts on some, a plain dict on others)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes per collective class from optimized HLO.

    Async pairs appear as op-start/op-done; only `-start` (or the sync form)
    lines carry the `(...)` operand list matched here, so nothing double
    counts. Tuple-shaped results count every element."""
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = re.search(
            r"=\s+(.+?)\s+(all-reduce-start|all-gather-start|reduce-scatter|"
            r"all-to-all|collective-permute-start|all-reduce|all-gather|"
            r"collective-permute)\(", line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        nbytes = 0
        for ty, dims in TUPLE_RE.findall(shape_str):
            if ty not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[ty]
        out[op] = out.get(op, 0.0) + float(nbytes)
    out["total"] = float(sum(v for k, v in out.items() if k != "total"))
    return out


def input_specs(arch_name: str, shape_name: str, mesh) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell
    (brief: MULTI-POD DRY-RUN step 2) — weak-type-correct, shardable, no
    device allocation."""
    arch = configs.get_config(arch_name)
    model = arch.model
    cell = C.SHAPES[shape_name]
    B, S = cell.global_batch, cell.seq_len
    bspec = SH.batch_spec(mesh, B)
    bsh = NamedSharding(mesh, bspec)

    def tok(shape):
        return jax.ShapeDtypeStruct(shape, jnp.int32, sharding=NamedSharding(
            mesh, P(*( [bspec[0]] + [None] * (len(shape) - 1) ))))

    if cell.mode in ("train", "prefill"):
        if model.input_kind == "tokens":
            return {"tokens": tok((B, S)), "labels": tok((B, S))}
        if model.input_kind == "embeddings":
            emb = jax.ShapeDtypeStruct(
                (B, S, model.d_model), jnp.bfloat16,
                sharding=NamedSharding(mesh, P(bspec[0], None, None)),
            )
            return {"embeddings": emb, "labels": tok((B, S))}
        # mixed (paligemma): n_prefix patch embeddings + text tokens
        tt = S - model.n_prefix
        emb = jax.ShapeDtypeStruct(
            (B, model.n_prefix, model.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, P(bspec[0], None, None)),
        )
        return {"prefix_embeddings": emb, "tokens": tok((B, tt)),
                "labels": tok((B, tt))}
    # decode: one token + positions (caches built separately)
    if model.input_kind == "embeddings":
        tok_in = jax.ShapeDtypeStruct(
            (B, 1, model.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, P(bspec[0], None, None)))
    else:
        tok_in = tok((B, 1))
    # synchronized decode: scalar position (collective-free cache writes —
    # EXPERIMENTS.md SecPerf iteration 4); ragged (B,) positions remain
    # supported for continuous batching.
    pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
    return {"tokens": tok_in, "pos": pos}


# archs whose bf16 KV cache exceeds 16 GB/chip on the single pod: serve with
# the int8 KV-quant cache (see DESIGN.md §4 / EXPERIMENTS.md §Dry-run).
KV_QUANT_DECODE = {"qwen1.5-32b"}


def _accum_steps(global_batch: int, seq: int, mesh) -> int:
    """Grad-accum so one microbatch is <= ~8k tokens per device."""
    dp = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape:
            dp *= mesh.shape[ax]
    per_dev = max(1, global_batch // dp)
    micro = max(1, 8192 // seq)
    return max(1, per_dev // micro)


def apply_variant(arch_name: str, model, variant: str, costmode: bool):
    """Per-arch beyond-baseline optimisation bundles (the SecPerf hillclimb
    variants). 'baseline' = paper-faithful/production default."""
    import dataclasses as _dc

    if variant == "baseline":
        return model
    if arch_name == "paligemma-3b":
        # hillclimb: sequence-parallel attention (MQA kv=1 cannot head-shard)
        bspec = ("pod", "data") if "pod" in [a for a in ("pod",)] else ("data",)
        bspec = ("data",)  # single-pod hillclimb cell
        def fix(b):
            if b.attn is not None:
                return _dc.replace(b, attn=_dc.replace(
                    b.attn, sp_spec=(bspec, "model", None, None)))
            return b
        return _dc.replace(model, unit=tuple(fix(b) for b in model.unit))
    if arch_name == "xlstm-1.3b":
        # hillclimb: chunked-parallel mLSTM (tests prove exact equivalence)
        def fix(b):
            if b.xlstm is not None:
                return _dc.replace(b, xlstm=_dc.replace(
                    b.xlstm, mlstm_impl="chunked", chunk=256,
                    scan_unroll=costmode))
            return b
        return _dc.replace(model, unit=tuple(fix(b) for b in model.unit))
    return model


def run_cell(
    arch_name: str, shape_name: str, mesh_kind: str, costmode: bool = False,
    variant: str = "baseline",
) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    arch = configs.get_config(arch_name)
    cell = C.SHAPES[shape_name]
    if cell.mode == "decode" and arch_name in KV_QUANT_DECODE:
        arch = C.enable_kv_quant(arch)
    model = apply_variant(arch_name, arch.model, variant, costmode)
    if costmode:
        return run_cell_cost(arch_name, model, cell, mesh, mesh_kind)
    result = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
        "mode": cell.mode, "seq_len": cell.seq_len,
        "global_batch": cell.global_batch,
        "n_devices": int(mesh.devices.size),
    }

    axes = lm.param_axes(model)
    # bf16 compute params everywhere; training keeps an fp32 ZeRO-sharded
    # master copy in the optimizer state (SecPerf iteration 2)
    pdtype = jnp.bfloat16
    abs_params = lm.abstract_params(model, dtype=pdtype)
    pshard = SH.tree_shardings(axes, abs_params, mesh)
    params_in = SH.with_sharded_leaves(abs_params, pshard)
    import math
    n_params = sum(math.prod(l.shape) for l in jax.tree.leaves(abs_params))
    result["n_params"] = n_params

    inputs = input_specs(arch_name, shape_name, mesh)

    with mesh:
        if cell.mode == "train":
            accum = _accum_steps(cell.global_batch, cell.seq_len, mesh)
            result["accum_steps"] = accum
            opt_cfg = adamw.AdamWConfig(master_weights=True)
            tstep = train_step_lib.make_train_step(
                model, opt_cfg, compute_dtype=jnp.bfloat16, accum_steps=accum
            )
            abs_opt = jax.eval_shape(
                lambda p: adamw.init_state(p, master_weights=True), abs_params
            )
            opt_m_sh = SH.tree_zero_shardings(axes, abs_params, mesh)
            opt_shard = {
                "m": opt_m_sh, "v": opt_m_sh, "master": opt_m_sh,
                "step": NamedSharding(mesh, P()),
            }
            opt_in = SH.with_sharded_leaves(abs_opt, opt_shard)
            lowered = jax.jit(
                tstep,
                out_shardings=(pshard, opt_shard, None),
                donate_argnums=(0, 1),
            ).lower(params_in, opt_in, inputs)
        elif cell.mode == "prefill":
            cax = lm.cache_axes(model)
            abs_caches = lm.abstract_caches(
                model, cell.global_batch, cell.seq_len, jnp.bfloat16
            )
            cache_shard = SH.tree_shardings(cax, abs_caches, mesh)

            def prefill_fn(p, inp):
                return lm.prefill(p, model, inp, cell.seq_len, jnp.bfloat16)

            lowered = jax.jit(
                prefill_fn, out_shardings=(None, cache_shard)
            ).lower(params_in, inputs)
        else:  # decode
            cax = lm.cache_axes(model)
            abs_caches = lm.abstract_caches(
                model, cell.global_batch, cell.seq_len, jnp.bfloat16
            )
            cache_shard = SH.tree_shardings(cax, abs_caches, mesh)
            caches_in = SH.with_sharded_leaves(abs_caches, cache_shard)

            def serve_step(p, tok, caches, pos):
                return lm.decode_step(p, model, tok, caches, pos, jnp.bfloat16)

            lowered = jax.jit(
                serve_step, out_shardings=(None, cache_shard),
                donate_argnums=(2,),   # caches update in place
            ).lower(params_in, inputs["tokens"], caches_in, inputs["pos"])

        result["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t1, 2)

    ma = compiled.memory_analysis()
    result["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "peak_bytes": int(ma.peak_memory_in_bytes),
    }
    ca = cost_analysis_dict(compiled)
    result["cost"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
    }
    result["collectives"] = collective_bytes(compiled.as_text())
    result["total_s"] = round(time.time() - t0, 2)
    return result



def _lower_costfaithful(model, cell, mesh, arch_name, n_rep):
    """Lower one cost-faithful variant: python-looped unit (n_rep repeats),
    no inner attention chunking (FLOP-equivalent), accum=1, remat as prod."""
    import dataclasses as _dc

    mvar = _dc.replace(
        model, scan_layers=False, n_repeats=n_rep,
        attn_chunk=max(cell.seq_len, 1),
    )
    axes = lm.param_axes(mvar)
    pdtype = jnp.bfloat16
    abs_params = lm.abstract_params(mvar, dtype=pdtype)
    pshard = SH.tree_shardings(axes, abs_params, mesh)
    params_in = SH.with_sharded_leaves(abs_params, pshard)
    inputs = input_specs(arch_name, cell.name, mesh)
    with mesh:
        if cell.mode == "train":
            opt_cfg = adamw.AdamWConfig(master_weights=True)
            tstep = train_step_lib.make_train_step(
                mvar, opt_cfg, compute_dtype=jnp.bfloat16, accum_steps=1
            )
            abs_opt = jax.eval_shape(
                lambda p: adamw.init_state(p, master_weights=True), abs_params
            )
            opt_m_sh = SH.tree_zero_shardings(axes, abs_params, mesh)
            opt_shard = {"m": opt_m_sh, "v": opt_m_sh, "master": opt_m_sh,
                         "step": NamedSharding(mesh, P())}
            opt_in = SH.with_sharded_leaves(abs_opt, opt_shard)
            lowered = jax.jit(
                tstep, out_shardings=(pshard, opt_shard, None)
            ).lower(params_in, opt_in, inputs)
        elif cell.mode == "prefill":
            cax = lm.cache_axes(mvar)
            abs_caches = lm.abstract_caches(
                mvar, cell.global_batch, cell.seq_len, jnp.bfloat16)
            cache_shard = SH.tree_shardings(cax, abs_caches, mesh)
            lowered = jax.jit(
                lambda p, inp: lm.prefill(p, mvar, inp, cell.seq_len, jnp.bfloat16),
                out_shardings=(None, cache_shard),
            ).lower(params_in, inputs)
        else:
            cax = lm.cache_axes(mvar)
            abs_caches = lm.abstract_caches(
                mvar, cell.global_batch, cell.seq_len, jnp.bfloat16)
            cache_shard = SH.tree_shardings(cax, abs_caches, mesh)
            caches_in = SH.with_sharded_leaves(abs_caches, cache_shard)
            lowered = jax.jit(
                lambda p, tok, cc, pos: lm.decode_step(
                    p, mvar, tok, cc, pos, jnp.bfloat16),
                out_shardings=(None, cache_shard),
            ).lower(params_in, inputs["tokens"], caches_in, inputs["pos"])
        compiled = lowered.compile()
    ca = cost_analysis_dict(compiled)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collectives": collective_bytes(compiled.as_text()),
    }


def run_cell_cost(arch_name, model, cell, mesh, mesh_kind) -> dict:
    """Cost-faithful per-device costs (EXPERIMENTS.md SecRoofline).

    XLA cost_analysis counts while-loop bodies once; here all loops are
    eliminated (python-looped unit at R'=1,2 with linear extrapolation to
    the true depth; attention unchunked — FLOP/byte-equivalent; the SSD
    inner scan carries only O(state) ops) except the xLSTM time recurrence,
    which gets an analytic adder (models/costs.py). Gradient accumulation is
    folded analytically (x accum of the accum=1 step)."""
    from repro.models import costs as costs_lib

    t0 = time.time()
    f1 = _lower_costfaithful(model, cell, mesh, arch_name, 1)
    f2 = _lower_costfaithful(model, cell, mesh, arch_name, 2)
    R = model.n_repeats
    # NOTE: the cost graph uses accum=1, which already covers the FULL
    # per-device batch in one microbatch — token-identical to the production
    # accum>1 graph, so no scaling is applied (validated: fwd flops match
    # the analytic 2ND+attention within 2%).

    def extrap(a, b):
        return a + (R - 1) * (b - a)

    out = {
        "arch": arch_name, "shape": cell.name, "mesh": mesh_kind,
        "mode": cell.mode,
        "flops": extrap(f1["flops"], f2["flops"]),
        "bytes_accessed": extrap(f1["bytes_accessed"], f2["bytes_accessed"]),
        "collectives": {},
    }
    keys = set(f1["collectives"]) | set(f2["collectives"])
    for k in keys:
        out["collectives"][k] = extrap(
            f1["collectives"].get(k, 0.0), f2["collectives"].get(k, 0.0)
        )
    # per-device batch/tokens for the adder (costs are per-device programs)
    dp = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape:
            dp *= mesh.shape[ax]
    per_dev_batch = max(1, cell.global_batch // dp)
    T = cell.seq_len if cell.mode != "decode" else 1
    adders = costs_lib.recurrent_adders(model, per_dev_batch, T, cell.mode)
    out["recurrent_adder"] = adders
    out["flops"] += adders["flops"]
    out["bytes_accessed"] += adders["bytes"]
    # reference quantities for the useful-compute ratio
    global_tokens = cell.global_batch * (cell.seq_len if cell.mode != "decode" else 1)
    out["model_flops_global"] = costs_lib.model_flops(
        model, global_tokens, cell.mode)
    out["n_active_params"] = costs_lib.n_active_params(model)
    out["total_s"] = round(time.time() - t0, 2)
    return out


def cells_for(arch_name: str) -> list[str]:
    return configs.get_config(arch_name).cells()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--costmode", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--include-kanformer", action="store_true", default=True)
    args = ap.parse_args()
    os.makedirs(ART_DIR, exist_ok=True)

    if args.all:
        jobs = []
        archs = list(configs.ASSIGNED) + (
            ["kanformer-100m"] if args.include_kanformer else []
        )
        for arch in archs:
            for shape in cells_for(arch):
                for mesh in ("single", "multi"):
                    out = os.path.join(
                        ART_DIR, f"{arch}__{shape}__{mesh}.json".replace("/", "_")
                    )
                    if not os.path.exists(out):
                        jobs.append((arch, shape, mesh, out, False))
                    # cost-faithful companion (single-pod only: SecRoofline)
                    outc = os.path.join(
                        ART_DIR, f"{arch}__{shape}__{mesh}__cost.json".replace("/", "_")
                    )
                    if mesh == "single" and not os.path.exists(outc):
                        jobs.append((arch, shape, mesh, outc, True))
        print(f"{len(jobs)} cells to run, {args.jobs} workers")
        running: list[tuple[subprocess.Popen, tuple]] = []
        failed = []
        while jobs or running:
            while jobs and len(running) < args.jobs:
                arch, shape, mesh, out, cost = jobs.pop(0)
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mesh] + (
                       ["--costmode"] if cost else [])
                p = subprocess.Popen(
                    cmd, stdout=subprocess.DEVNULL, stderr=subprocess.PIPE
                )
                running.append((p, (arch, shape, mesh, out)))
                print(f"[start] {arch} {shape} {mesh}")
            time.sleep(2)
            still = []
            for p, meta in running:
                if p.poll() is None:
                    still.append((p, meta))
                else:
                    ok = p.returncode == 0 and os.path.exists(meta[3])
                    print(f"[{'done' if ok else 'FAIL'}] {meta[0]} {meta[1]} {meta[2]}")
                    if not ok:
                        err = p.stderr.read().decode()[-2000:]
                        failed.append((meta, err))
                        print(err[-800:])
            running = still
        print(f"finished; {len(failed)} failures")
        for meta, err in failed:
            print("FAILED:", meta[:3])
        sys.exit(1 if failed else 0)

    # single-cell mode
    assert args.arch and args.shape
    try:
        result = run_cell(args.arch, args.shape, args.mesh,
                          costmode=args.costmode, variant=args.variant)
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    suffix = ("__cost" if args.costmode else "") + (
        f"__{args.variant}" if args.variant != "baseline" else "")
    out = os.path.join(
        ART_DIR,
        f"{args.arch}__{args.shape}__{args.mesh}{suffix}.json".replace("/", "_"),
    )
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps({k: v for k, v in result.items() if k != "collectives"}))
    print("wrote", out)


if __name__ == "__main__":
    main()
