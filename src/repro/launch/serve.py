"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Batched request serving (prefill + decode with KV caches) on the host mesh;
the production-mesh serve_step is exercised by the dry-run decode cells."""

from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve.engine import Engine, ServeConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_configs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = configs.get_reduced(args.arch) if args.reduced else configs.get_config(args.arch)
    model = arch.model
    if model.input_kind != "tokens":
        print(f"[serve] {args.arch} is {model.input_kind}-input; serving the "
              f"token path is exercised via mixed/embeddings archs in tests")
    params = lm.init_params(jax.random.PRNGKey(args.seed), model)
    eng = Engine(
        params, model,
        ServeConfig(max_seq=args.prompt_len + args.max_new + 8,
                    max_new_tokens=args.max_new, temperature=args.temperature),
    )
    rs = np.random.RandomState(args.seed)
    reqs = [
        rs.randint(0, model.vocab, rs.randint(4, args.prompt_len + 1)).astype(np.int32)
        for _ in range(args.requests)
    ]
    t0 = time.time()
    outs = eng.serve_requests(reqs, batch_size=args.batch, seed=args.seed)
    dt = time.time() - t0
    total_new = sum(len(o) for o in outs)
    print(f"[serve] {len(reqs)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s) on {jax.default_backend()}")
    print("sample output ids:", outs[0][:10].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
