"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Batched request serving (prefill + decode with KV caches) on the host mesh;
the production-mesh serve_step is exercised by the dry-run decode cells.

``--engine static`` drains length-sorted fixed buckets
(``Engine.serve_requests``); ``--engine continuous`` runs the slot-recycling
continuous-batching loop (``Engine.serve_continuous``) and reports its slot
utilization.  ``--paged`` (continuous only) switches the KV cache to the
paged block pool with prefix caching and preemption (DESIGN.md §3b);
``--block-size``/``--pool-blocks`` shape the pool.  ``--mesh DxM`` serves
on a (data, model) host mesh (DESIGN.md §4: params/KV sharded, outputs
identical to the single-device engine).  ``--spec-k K`` (continuous only)
turns on speculative decoding: a shrunken-KAN drafter (``--draft-layers``,
optionally ``--draft-quant``) proposes K tokens per window and one fused
verify pass scores them — outputs stay bit-identical to ``--spec-k 0``
(DESIGN.md §9).  Reduced (CPU-runnable) shapes are the default; ``--full``
selects the full production config.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro import configs
from repro.launch.mesh import make_host_mesh, parse_mesh_shape
from repro.models import lm
from repro.serve.engine import Engine, ServeConfig


def pick_config(arch: str, full: bool):
    """Reduced shapes by default; ``--full`` opts into the production
    config.  (The previous ``--reduced`` flag was ``store_true`` with
    ``default=True`` — impossible to turn off.)"""
    return configs.get_config(arch) if full else configs.get_reduced(arch)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_configs())
    ap.add_argument("--full", action="store_true",
                    help="use the full production config (default: reduced)")
    ap.add_argument("--engine", choices=("static", "continuous"),
                    default="static")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4,
                    help="static: bucket size; continuous: slot count")
    ap.add_argument("--chunk-steps", type=int, default=8,
                    help="continuous: decode steps per jitted chunk")
    ap.add_argument("--paged", action="store_true",
                    help="continuous: paged KV cache (block pool + prefix "
                         "caching + preemption; DESIGN.md §3b)")
    ap.add_argument("--block-size", type=int, default=8,
                    help="paged: tokens per KV block (must divide max_seq)")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="paged: physical blocks incl. the sentinel "
                         "(default: dense-equivalent capacity)")
    ap.add_argument("--mesh", type=str, default=None, metavar="DxM",
                    help="serve on a (data, model) host mesh, e.g. 2x4 "
                         "(requires that many host devices; force with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count). "
                         "Default: single-device engine")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="continuous: speculative decoding — drafts per "
                         "verify window (0 disables; DESIGN.md §9). Outputs "
                         "stay bit-identical to --spec-k 0")
    ap.add_argument("--draft-layers", type=int, default=1,
                    help="speculative: leading unit repeats kept in the "
                         "derived drafter (1..n_repeats)")
    ap.add_argument("--draft-quant", action="store_true",
                    help="speculative: int8 fake-quantize the drafter "
                         "weights (KANtize-style)")
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--eos-id", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)

    arch = pick_config(args.arch, args.full)
    model = arch.model
    if model.input_kind != "tokens":
        print(f"[serve] {args.arch} is {model.input_kind}-input; serving the "
              f"token path is exercised via mixed/embeddings archs in tests")
    if args.paged and args.engine != "continuous":
        print("[serve] --paged requires --engine continuous", file=sys.stderr)
        return 2
    if args.spec_k < 0:
        print(f"[serve] --spec-k must be >= 0, got {args.spec_k}",
              file=sys.stderr)
        return 2
    if args.spec_k > 0 and args.engine != "continuous":
        print("[serve] --spec-k requires --engine continuous", file=sys.stderr)
        return 2
    if not (1 <= args.draft_layers <= model.n_repeats):
        print(f"[serve] --draft-layers must be in [1, {model.n_repeats}] "
              f"for {args.arch}, got {args.draft_layers}", file=sys.stderr)
        return 2
    if args.spec_k > 0 and not lm.model_supports_speculative(model):
        print(f"[serve] {args.arch} does not support speculative decoding "
              f"(needs token-input full-attention GQA blocks)",
              file=sys.stderr)
        return 2
    params = lm.init_params(jax.random.PRNGKey(args.seed), model)
    max_seq = args.prompt_len + args.max_new + 8
    if args.paged:   # the paged pool addresses whole blocks
        max_seq = -(-max_seq // args.block_size) * args.block_size
    mesh = None
    if args.mesh is not None:
        try:
            mesh = make_host_mesh(parse_mesh_shape(args.mesh))
        except ValueError as e:
            print(f"[serve] {e}", file=sys.stderr)
            return 2
        print(f"[serve] mesh={dict(mesh.shape)} over {mesh.size} "
              f"of {len(jax.devices())} host devices")
    eng = Engine(
        params, model,
        ServeConfig(max_seq=max_seq,
                    max_new_tokens=args.max_new, temperature=args.temperature,
                    eos_id=args.eos_id, paged=args.paged,
                    block_size=args.block_size, pool_blocks=args.pool_blocks,
                    mesh=mesh, spec_k=args.spec_k,
                    draft_layers=args.draft_layers,
                    draft_quant=args.draft_quant),
    )
    rs = np.random.RandomState(args.seed)
    reqs = [
        rs.randint(0, model.vocab, rs.randint(4, args.prompt_len + 1)).astype(np.int32)
        for _ in range(args.requests)
    ]
    t0 = time.time()
    if args.engine == "continuous":
        outs = eng.serve_continuous(
            reqs, slots=args.batch, chunk_steps=args.chunk_steps,
            seed=args.seed,
        )
    else:
        outs = eng.serve_requests(reqs, batch_size=args.batch, seed=args.seed)
    dt = time.time() - t0
    total_new = sum(len(o) for o in outs)
    print(f"[serve:{args.engine}] {len(reqs)} requests, {total_new} tokens "
          f"in {dt:.2f}s ({total_new/dt:.1f} tok/s) on {jax.default_backend()}")
    if args.engine == "continuous" and eng.last_serve_stats:
        s = eng.last_serve_stats
        print(f"[serve:continuous] slot_utilization="
              f"{s['mean_slot_utilization']:.3f} chunks={s['chunks_run']} "
              f"served={s['n_served']}/{s['n_submitted']}")
        if args.paged:
            p = s["paged"]
            print(f"[serve:paged] block_size={p['block_size']} "
                  f"blocks_watermark={p['blocks_in_use_watermark']}"
                  f"/{p['pool_blocks'] - 1} "
                  f"prefix_hit_blocks={p.get('prefix_hit_blocks', 0)} "
                  f"prefill_tokens_saved={p['prefill_tokens_saved']} "
                  f"preemptions={s['n_preemptions']}")
        if args.spec_k > 0:
            sp = s["spec"]
            print(f"[serve:spec] k={sp['spec_k']} "
                  f"draft_layers={sp['draft_layers']} "
                  f"windows={sp['windows']} "
                  f"acceptance_rate={sp['acceptance_rate']:.3f} "
                  f"emitted={sp['emitted_tokens']}")
    print("sample output ids:", outs[0][:10].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
