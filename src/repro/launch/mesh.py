"""Production mesh construction (brief: MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (TPU v5e pod slice); 2x16x16 = 512 multi-pod.

    Axis roles (DESIGN.md §4): ``pod`` = data-parallel across pods (slow
    inter-pod links carry only gradient all-reduces / batch splits),
    ``data`` = in-pod DP + KV-cache seq sharding, ``model`` = TP/EP.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape: tuple[int, int] | None = None):
    """Host ``(data, model)`` mesh for CPU smoke/examples/serving.

    Default (``shape=None``) keeps the historical behavior: every host
    device lands on ``model`` (``(1, n)``).  That forced shape made data
    parallelism impossible on a host mesh — pass ``shape=(data, model)``
    to choose the split (e.g. ``(2, 4)`` on a forced-8-device host).  The
    requested mesh may use a subset of the host's devices, but its size
    must divide the device count (no stranded remainder)."""
    n = len(jax.devices())
    if shape is None:
        return jax.make_mesh((1, n), ("data", "model"))
    d, m = int(shape[0]), int(shape[1])
    if d < 1 or m < 1:
        raise ValueError(f"mesh shape must be positive, got {(d, m)}")
    if d * m > n or n % (d * m):
        raise ValueError(
            f"host mesh {d}x{m} needs {d * m} devices but the host offers "
            f"{n} ({'too few' if d * m > n else 'not divisible'}); force "
            f"more with XLA_FLAGS=--xla_force_host_platform_device_count=N"
        )
    return jax.make_mesh((d, m), ("data", "model"))


def parse_mesh_shape(s: str) -> tuple[int, int]:
    """``"2x4"`` -> ``(2, 4)`` — the ``--mesh dxm`` CLI flag format."""
    parts = s.lower().split("x")
    if len(parts) != 2:
        raise ValueError(f"--mesh expects DxM (e.g. 2x4), got {s!r}")
    try:
        d, m = int(parts[0]), int(parts[1])
    except ValueError:
        raise ValueError(f"--mesh expects integers DxM, got {s!r}") from None
    return d, m
