"""Production mesh construction (brief: MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (TPU v5e pod slice); 2x16x16 = 512 multi-pod.

    Axis roles (DESIGN.md §4): ``pod`` = data-parallel across pods (slow
    inter-pod links carry only gradient all-reduces / batch splits),
    ``data`` = in-pod DP + KV-cache seq sharding, ``model`` = TP/EP.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host offers (CPU smoke/examples): 1 device -> 1x1."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
