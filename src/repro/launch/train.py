"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Production posture (DESIGN.md §4):
* sharded via the logical-axis rules onto whatever mesh the host offers
  (the production mesh shape is exercised by the dry-run);
* checkpoint/restart: atomic async checkpoints every ``--ckpt-every`` steps,
  auto-resume from the latest valid one, checkpoint-on-SIGTERM/SIGINT
  (pre-emption handling), bounded retry around the step;
* deterministic data: batch = f(seed, step), so restarts never skip/replay.
"""

from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import store
from repro.data import pipeline as dp
from repro.dist import sharding as SH
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.optim import adamw
from repro.train import step as train_step_lib


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_configs())
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-size config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compute-dtype", default="float32")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--max-retries", type=int, default=2)
    args = ap.parse_args(argv)

    arch = configs.get_reduced(args.arch) if args.reduced else configs.get_config(args.arch)
    model = arch.model
    mesh = make_host_mesh()
    cdtype = jnp.float32 if args.compute_dtype == "float32" else jnp.bfloat16

    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps, warmup_steps=min(20, args.steps // 10 + 1))
    tstep = train_step_lib.make_train_step(
        model, opt_cfg, compute_dtype=cdtype, accum_steps=args.accum
    )

    axes = lm.param_axes(model)
    params = lm.init_params(jax.random.PRNGKey(args.seed), model)
    pshard = SH.tree_shardings(axes, jax.eval_shape(lambda: params), mesh)
    params = jax.tree.map(jax.device_put, params, pshard)
    opt_state = adamw.init_state(params)

    start_step = 0
    ckpt = store.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt is not None:
        latest = store.latest_step(args.ckpt_dir)
        if latest is not None:
            (params, opt_state), mf = store.restore(
                args.ckpt_dir, latest, (params, opt_state)
            )
            start_step = mf["step"]
            print(f"[resume] restored step {start_step} from {args.ckpt_dir}")

    stop = {"flag": False}

    def _on_signal(signum, frame):
        print(f"[signal] {signum}: checkpoint-and-exit requested")
        stop["flag"] = True

    signal.signal(signal.SIGTERM, _on_signal)
    signal.signal(signal.SIGINT, _on_signal)

    data_cfg = dp.LMDataConfig(
        vocab=model.vocab, seq_len=args.seq, global_batch=args.batch, seed=args.seed
    )
    jit_step = jax.jit(tstep, donate_argnums=(0, 1))
    t_start = time.time()
    losses = []
    step_i = start_step
    while step_i < args.steps and not stop["flag"]:
        batch = dp.lm_batch(data_cfg, step_i)
        if model.input_kind == "embeddings":
            rs = np.random.RandomState(step_i)
            batch = {
                "embeddings": jnp.asarray(
                    rs.normal(size=(args.batch, args.seq, model.d_model)).astype(np.float32)
                ),
                "labels": batch["labels"],
            }
        elif model.input_kind == "mixed":
            rs = np.random.RandomState(step_i)
            batch = {
                "prefix_embeddings": jnp.asarray(
                    rs.normal(size=(args.batch, model.n_prefix, model.d_model)).astype(np.float32)
                ),
                "tokens": batch["tokens"],
                "labels": batch["labels"],
            }
        for attempt in range(args.max_retries + 1):
            try:
                params, opt_state, metrics = jit_step(params, opt_state, batch)
                break
            except Exception as e:  # bounded retry (transient failures)
                if attempt == args.max_retries:
                    raise
                print(f"[retry] step {step_i} attempt {attempt + 1}: {e}")
        step_i += 1
        loss = float(metrics["loss"])
        losses.append(loss)
        if step_i % args.log_every == 0 or step_i == args.steps:
            dt = time.time() - t_start
            tok_s = step_i * args.batch * args.seq / max(dt, 1e-9)
            print(
                f"step {step_i:5d} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"lr {float(metrics['lr']):.2e} tok/s {tok_s:,.0f}"
            )
        if ckpt is not None and (step_i % args.ckpt_every == 0 or stop["flag"]):
            ckpt.save_async(step_i, (params, opt_state), {"loss": loss})
    if ckpt is not None:
        ckpt.save_async(step_i, (params, opt_state), {"loss": losses[-1] if losses else None})
        ckpt.wait()
    first = float(np.mean(losses[:10])) if len(losses) >= 10 else (losses[0] if losses else float("nan"))
    last = float(np.mean(losses[-10:])) if losses else float("nan")
    print(f"[done] steps={step_i} loss {first:.4f} -> {last:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
