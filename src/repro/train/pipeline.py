"""GPipe-style pipeline parallelism across the ``pod`` mesh axis.

Rationale (DESIGN.md §4): inter-pod ICI is the slowest link in a multi-pod
system, and pipeline-stage handoff (one activation tensor per microbatch,
point-to-point) is the cheapest traffic to put there — DP gradients
all-reduce 2x params per step, PP moves M x (mb x T x d) activations.

Mechanics: the layer stack (a uniform unit, ``n_repeats`` deep) is split
into S = pod-size stages; stage parameters are stacked on a leading axis
sharded over ``pod``, so inside ``jax.shard_map`` (manual over {pod}, auto
over data/model — TP/DP still handled by GSPMD) each pod sees only its own
stage. The classic looped schedule runs M + S - 1 ticks; activations hop
stages via ``ppermute``; the last stage accumulates the loss, and a psum
over ``pod`` makes the result provably pod-invariant. Backward is pure
autodiff through the loop (GPipe activation stashing).

Embedding/unembedding run on every stage and are masked — wasted FLOPs of
one embed+logits per tick, the standard simple-GPipe tradeoff (noted in
EXPERIMENTS.md); production would dedicate them to stages 0/S-1.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.5 exposes shard_map at the top level (axis_names/check_vma)
    from jax import shard_map
except ImportError:  # 0.4.x: experimental module; partial-auto (auto=...)
    # trips an XLA partitioner limitation, so fall back to FULL-manual over
    # all mesh axes.  Equivalent here: the PP body only names "pod" and its
    # other operands are replicated over data/model — each (data, model)
    # replica just redundantly computes the same (correct) loss.
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map(f, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=None, **_kw):
        return _shard_map_04(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=bool(check_vma),
        )

from repro.models import blocks as B
from repro.models import layers as L
from repro.models import lm


def stage_params(params: dict, n_stages: int) -> dict:
    """Reshape stacked unit params (R, ...) -> (S, R/S, ...)."""
    def resh(a):
        return a.reshape((n_stages, a.shape[0] // n_stages) + a.shape[1:])

    out = dict(params)
    out["unit"] = [jax.tree.map(resh, p) for p in params["unit"]]
    return out


def make_pp_loss(model_cfg, n_stages: int, microbatches: int, mesh,
                 compute_dtype=jnp.bfloat16):
    """-> loss_fn(params_staged, batch) running the pipelined forward.

    Requires: uniform single-block unit, no prologue/epilogue/shared,
    n_repeats % n_stages == 0, global_batch % microbatches == 0.
    """
    cfg = model_cfg
    assert len(cfg.unit) == 1 and not cfg.prologue and not cfg.epilogue
    assert cfg.n_repeats % n_stages == 0
    blk = cfg.unit[0]

    def body(unit_local, embed_p, ln_p, tokens, labels):
        # unit_local: (1, R/S, ...) — my stage's slice (leading pod dim)
        unit_local = jax.tree.map(lambda a: a[0], unit_local)
        s = jax.lax.axis_index("pod")
        M = microbatches
        Bm, T = tokens.shape[1], tokens.shape[2]
        d = cfg.d_model
        state = jnp.zeros((Bm, T, d), compute_dtype)
        loss_sum = jnp.zeros((), jnp.float32)
        tok_sum = jnp.zeros((), jnp.float32)

        def stage_apply(h):
            def unit_body(h_c, rep_params):
                h_c, _ = B.block_apply(
                    rep_params, blk, h_c, positions=jnp.arange(T)[None, :],
                    chunk=cfg.attn_chunk,
                )
                return h_c, None

            h, _ = jax.lax.scan(unit_body, h, unit_local)
            return h

        for t in range(M + n_stages - 1):
            mb_in = min(t, M - 1)
            mb_out = t - (n_stages - 1)
            inject = L.embed_lookup(embed_p, tokens[mb_in], compute_dtype) * \
                math.sqrt(d)
            x = jnp.where(s == 0, inject, state)
            x = stage_apply(x)
            # last stage: loss for microbatch mb_out (if valid)
            h = L.rmsnorm(ln_p, x)
            logits = L.unembed_logits(embed_p, h)
            lbl = labels[max(0, min(mb_out, M - 1))]
            mask = (lbl >= 0).astype(jnp.float32)
            lbl_c = jnp.clip(lbl, 0, cfg.vocab - 1)
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(logp, lbl_c[..., None], axis=-1)[..., 0]
            valid = jnp.logical_and(s == n_stages - 1, 0 <= mb_out)
            loss_sum += jnp.where(valid, (nll * mask).sum(), 0.0)
            tok_sum += jnp.where(valid, mask.sum(), 0.0)
            # hop activations to the next stage
            state = jax.lax.ppermute(
                x, "pod", [(i, i + 1) for i in range(n_stages - 1)]
            )
        loss_sum = jax.lax.psum(loss_sum, "pod")
        tok_sum = jax.lax.psum(tok_sum, "pod")
        return loss_sum / jnp.maximum(tok_sum, 1.0)

    smapped = shard_map(
        body,
        mesh=mesh,
        # pytree-prefix specs: the stage-stacked unit tree is pod-sharded on
        # its leading axis; everything else is pod-replicated (data/model
        # sharding stays with GSPMD — only {pod} is manual here).
        in_specs=(P("pod"), P(), P(), P(), P()),
        out_specs=P(),
        axis_names={"pod"},
        check_vma=False,
    )

    def loss_fn(params_staged, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        Bg, T = tokens.shape
        mb = Bg // microbatches
        tok_m = tokens.reshape(microbatches, mb, T)
        lbl_m = labels.reshape(microbatches, mb, T)
        unit0 = params_staged["unit"][0]
        return smapped(
            unit0, params_staged["embed"], params_staged["final_ln"],
            tok_m, lbl_m,
        )

    return loss_fn
