"""Training step: grad accumulation (scan), mixed precision, pjit-ready.

``make_train_step`` returns a pure ``(params, opt_state, batch, step) ->
(params, opt_state, metrics)`` function. Gradient accumulation is a
``lax.scan`` over microbatches, so under DP the gradient all-reduce (inserted
by GSPMD at the psum of the final update) overlaps the last microbatch's
backward with XLA's latency-hiding scheduler (DESIGN.md §4).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.optim import adamw
from repro.dist import compression


def make_train_step(
    model_cfg,
    opt_cfg: adamw.AdamWConfig,
    *,
    compute_dtype=jnp.bfloat16,
    accum_steps: int = 1,
    grad_compression: str | None = None,   # None | "int8" | "bf16"
) -> Callable:
    def loss_fn(params, batch):
        return lm.lm_loss(params, model_cfg, batch, compute_dtype)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l), None

            micro_batches = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps) + x.shape[1:]),
                batch,
            )
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(micro, (g0, 0.0), micro_batches)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss_sum / accum_steps
            metrics = {"loss": loss}
        if grad_compression:
            grads = compression.compress_tree(grads, grad_compression)
        params, opt_state, opt_metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg
        )
        metrics = {**metrics, **opt_metrics}
        return params, opt_state, metrics

    return train_step
