"""AdamW + schedules + global-norm clipping (hand-built; no optax here).

State layout is a pytree congruent with params, so the sharding rules apply
to optimizer state verbatim (m/v inherit the param's PartitionSpec) — this
is what makes the optimizer ZeRO-free but fully sharded under TP and cheap
under DP (state is replicated only where params are).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    schedule: str = "cosine"    # "cosine" | "linear" | "constant"
    # Mixed-precision training: keep compute params in bf16 and an fp32
    # MASTER copy in the optimizer state (ZeRO-sharded with m/v). Halves the
    # param + gradient HBM footprint (EXPERIMENTS.md §Perf iteration 2:
    # qwen1.5-32b train_4k 19.4 GB -> fits).
    master_weights: bool = False


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(1, cfg.warmup_steps))
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        t = jnp.clip(
            (s - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps),
            0.0, 1.0,
        )
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
                1 + jnp.cos(jnp.pi * t)
            )
        else:
            decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * (1 - t)
    return cfg.lr * warm * decay


def init_state(params, master_weights: bool = False) -> dict:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state = {"m": zeros(), "v": zeros(), "step": jnp.zeros((), jnp.int32)}
    if master_weights:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32) if hasattr(p, "astype")
            else jnp.zeros(p.shape, jnp.float32),
            params,
        )
    return state


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def apply_updates(
    params, grads, state: dict, cfg: AdamWConfig
) -> tuple[dict, dict, dict]:
    """-> (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p_ref, g, m, v):
        """p_ref is the fp32 master when enabled, else the param itself."""
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m_new / b1c
        vh = v_new / b2c
        p32 = p_ref.astype(jnp.float32)
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p32
        return p32 - lr * delta, m_new, v_new

    ref = state["master"] if cfg.master_weights else params
    out = jax.tree.map(upd, ref, grads, state["m"], state["v"])
    new_ref = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    if cfg.master_weights:
        new_state["master"] = new_ref
        new_params = jax.tree.map(
            lambda master, p: master.astype(p.dtype), new_ref, params
        )
    else:
        new_params = jax.tree.map(
            lambda r, p: r.astype(p.dtype), new_ref, params
        )
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
