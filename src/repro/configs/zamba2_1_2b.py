"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks. [arXiv:2411.15242; hf]

Layer layout (38 total): 6 x [5 mamba2 + shared-attention] + 2 mamba2.
The shared attention block is ONE parameter set invoked at 6 depths
(Zamba2's shared-block scheme, simplified: no per-invocation LoRA —
noted in DESIGN.md §5)."""

from repro.configs.common import ArchConfig
from repro.models.attention import AttnConfig
from repro.models.blocks import BlockCfg
from repro.models.lm import ModelConfig
from repro.models.ssm import Mamba2Config


def build(n_repeats=6, mamba_per_unit=5, tail=2, d_model=2048, n_heads=32,
          n_kv=32, d_ff=8192, vocab=32000, d_state=64) -> ArchConfig:
    attn = AttnConfig(
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=d_model // n_heads,
    )
    mamba = Mamba2Config(d_model=d_model, d_state=d_state)
    shared_attn = BlockCfg("attn_mlp", attn=attn, d_ff=d_ff)
    unit = tuple(
        [BlockCfg("mamba2", mamba=mamba)] * mamba_per_unit
        + [BlockCfg("attn_mlp", attn=attn, d_ff=d_ff, shared_id=0)]
    )
    model = ModelConfig(
        name="zamba2-1.2b", d_model=d_model, vocab=vocab,
        unit=unit, n_repeats=n_repeats,
        epilogue=tuple([BlockCfg("mamba2", mamba=mamba)] * tail),
        shared=(shared_attn,),
    )
    return ArchConfig(
        model=model, family="hybrid", sub_quadratic=True,
        source="arXiv:2411.15242",
        notes="long_500k: SSM state is O(1); the shared-attn KV cache "
              "seq-shards across the data axis.",
    )


def config() -> ArchConfig:
    return build()


def reduced() -> ArchConfig:
    return build(n_repeats=1, mamba_per_unit=2, tail=1, d_model=64,
                 n_heads=4, n_kv=4, d_ff=128, vocab=512, d_state=16)
