"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64 experts top-8. [arXiv:2409.02060; hf]"""

from repro.configs.common import ArchConfig
from repro.models.attention import AttnConfig
from repro.models.blocks import BlockCfg
from repro.models.lm import ModelConfig
from repro.models.moe import MoEConfig


def build(n_layers=16, d_model=2048, n_heads=16, n_kv=16, d_ff=1024,
          vocab=50304, n_experts=64, top_k=8) -> ArchConfig:
    attn = AttnConfig(
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=d_model // n_heads, qk_norm=True,
    )
    moe = MoEConfig(
        d_model=d_model, d_ff=d_ff, n_experts=n_experts, top_k=top_k,
    )
    model = ModelConfig(
        name="olmoe-1b-7b", d_model=d_model, vocab=vocab,
        unit=(BlockCfg("attn_moe", attn=attn, moe=moe),),
        n_repeats=n_layers,
    )
    return ArchConfig(
        model=model, family="moe", sub_quadratic=False,
        source="arXiv:2409.02060",
        notes="EP: 64 experts / model=16 -> 4 experts per device.",
    )


def config() -> ArchConfig:
    return build()


def reduced() -> ArchConfig:
    return build(n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=32,
                 vocab=512, n_experts=8, top_k=2)
