"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64e top-6 — MLA kv_lora=512, 2 shared + routed top-6.
[arXiv:2405.04434; hf]

The pool line's "160 routed" conflicts with its own "64e top-6"; we follow
the published DeepSeek-V2-Lite config: 64 routed experts, top-6, 2 shared
experts, first layer dense (d_ff 10944), MLA with kv_lora_rank=512,
qk_rope_head_dim=64, head_dim 128 (see DESIGN.md §5)."""

from repro.configs.common import ArchConfig
from repro.models.attention import AttnConfig
from repro.models.blocks import BlockCfg
from repro.models.lm import ModelConfig
from repro.models.moe import MoEConfig


def build(n_layers=27, d_model=2048, n_heads=16, d_ff_expert=1408,
          vocab=102400, n_experts=64, top_k=6, n_shared=2, kv_lora=512,
          dense_ff=10944, head_dim=128, qk_rope=64) -> ArchConfig:
    attn = AttnConfig(
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_heads,
        head_dim=head_dim, kv_lora_rank=kv_lora, qk_rope_dim=qk_rope,
    )
    moe = MoEConfig(
        d_model=d_model, d_ff=d_ff_expert, n_experts=n_experts, top_k=top_k,
        n_shared=n_shared,
    )
    model = ModelConfig(
        name="deepseek-v2-lite", d_model=d_model, vocab=vocab,
        prologue=(BlockCfg("attn_mlp", attn=attn, d_ff=dense_ff),),
        unit=(BlockCfg("attn_moe", attn=attn, moe=moe),),
        n_repeats=n_layers - 1,
    )
    return ArchConfig(
        model=model, family="moe", sub_quadratic=False,
        source="arXiv:2405.04434",
        notes="MLA latent KV cache: serve caches only (kv_lora+rope)=576 "
              "dims/token instead of 2*16*128=4096 (7.1x cache cut).",
    )


def config() -> ArchConfig:
    return build()


def reduced() -> ArchConfig:
    return build(n_layers=3, d_model=64, n_heads=4, d_ff_expert=32,
                 vocab=512, n_experts=8, top_k=2, n_shared=1, kv_lora=16,
                 dense_ff=128, head_dim=16, qk_rope=8)
