"""paligemma-3b [vlm]: 18L d_model=2048 8H (GQA kv=1) d_ff=16384
vocab=257216 — SigLIP + gemma. [arXiv:2407.07726; hf]

Backbone-only per the brief: the SigLIP vision tower is a STUB —
``input_specs()`` provides precomputed patch embeddings (B, 256, d) that
prefix the text tokens (the PaliGemma prefix-LM layout)."""

from repro.configs.common import ArchConfig
from repro.models.attention import AttnConfig
from repro.models.blocks import BlockCfg
from repro.models.lm import ModelConfig

N_PATCHES = 256


def build(n_layers=18, d_model=2048, n_heads=8, n_kv=1, d_ff=16384,
          vocab=257216, n_prefix=N_PATCHES) -> ArchConfig:
    attn = AttnConfig(
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=d_model // n_heads,
    )
    model = ModelConfig(
        name="paligemma-3b", d_model=d_model, vocab=vocab,
        unit=(BlockCfg("attn_mlp", attn=attn, d_ff=d_ff),),
        n_repeats=n_layers, input_kind="mixed", n_prefix=n_prefix,
    )
    return ArchConfig(
        model=model, family="vlm", sub_quadratic=False,
        source="arXiv:2407.07726",
        notes="SigLIP frontend stubbed (precomputed patch embeddings); "
              "kv=1 (MQA) replicates KV under TP.",
    )


def config() -> ArchConfig:
    return build()


def reduced() -> ArchConfig:
    return build(n_layers=2, d_model=64, n_heads=4, n_kv=1, d_ff=128,
                 vocab=512, n_prefix=8)
