"""musicgen-large [audio]: 48L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

Backbone-only per the brief: the EnCodec frontend is a STUB —
``input_specs()`` provides precomputed frame embeddings (B, S, d); the
4-codebook delay-pattern head collapses to a single 2048-way head."""

from repro.configs.common import ArchConfig
from repro.models.attention import AttnConfig
from repro.models.blocks import BlockCfg
from repro.models.lm import ModelConfig


def build(n_layers=48, d_model=2048, n_heads=32, n_kv=32, d_ff=8192,
          vocab=2048) -> ArchConfig:
    attn = AttnConfig(
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=d_model // n_heads,
    )
    model = ModelConfig(
        name="musicgen-large", d_model=d_model, vocab=vocab,
        unit=(BlockCfg("attn_mlp", attn=attn, d_ff=d_ff),),
        n_repeats=n_layers, input_kind="embeddings",
    )
    return ArchConfig(
        model=model, family="audio", sub_quadratic=False,
        source="arXiv:2306.05284",
        notes="EnCodec frontend stubbed (precomputed frame embeddings); "
              "sinusoidal positions replaced by rotary (DESIGN.md §5).",
    )


def config() -> ArchConfig:
    return build()


def reduced() -> ArchConfig:
    return build(n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=128)
