"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global, 128k context.
[hf:google/gemma-3-1b-pt; unverified] head_dim=256, sliding window 1024,
qk-RMSNorm (Gemma-3 family)."""

from repro.configs.common import ArchConfig
from repro.models.attention import AttnConfig
from repro.models.blocks import BlockCfg
from repro.models.lm import ModelConfig


def build(n_repeats=8, d_model=3840, n_heads=16, n_kv=8, d_ff=15360,
          vocab=262144, head_dim=256, window=1024) -> ArchConfig:
    local = AttnConfig(
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv, head_dim=head_dim,
        window=window, qk_norm=True, rope_theta=10000.0,
    )
    glob = AttnConfig(
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv, head_dim=head_dim,
        window=None, qk_norm=True, rope_theta=1e6,
    )
    unit = tuple(
        [BlockCfg("attn_mlp", attn=local, d_ff=d_ff)] * 5
        + [BlockCfg("attn_mlp", attn=glob, d_ff=d_ff)]
    )
    model = ModelConfig(
        name="gemma3-12b", d_model=d_model, vocab=vocab,
        unit=unit, n_repeats=n_repeats,
    )
    return ArchConfig(
        model=model, family="dense", sub_quadratic=True,
        source="hf:google/gemma-3-12b-pt (config per pool; unverified tier)",
        notes="5:1 local:global — 5/6 of layers are O(window); long_500k "
              "runs with the global layers' KV cache sequence-sharded "
              "across the data axis (DESIGN.md §5).",
    )


def config() -> ArchConfig:
    return build()


def reduced() -> ArchConfig:
    return build(n_repeats=1, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                 vocab=512, head_dim=16, window=8)
