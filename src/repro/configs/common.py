"""Config system: ArchConfig + the assigned shape cells + registry helpers.

Every assigned architecture ships as ``src/repro/configs/<id>.py`` exposing
``config()`` (the exact published hyperparameters) and ``reduced()`` (a tiny
same-family config for CPU smoke tests). The full configs are exercised only
through the dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses

from repro.models.lm import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    mode: str                   # "train" | "prefill" | "decode"


# The assigned LM shape set (brief): train/prefill lower ``train_step``/
# ``prefill``; decode_* and long_* lower ``serve_step`` (one token + KV cache).
SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def enable_kv_quant(arch: "ArchConfig") -> "ArchConfig":
    """Rebuild an ArchConfig with int8 KV caches on every GQA attention
    block (serving-memory feature; used by the dry-run where the bf16 cache
    exceeds HBM — see EXPERIMENTS.md §Dry-run)."""

    def fix_block(b):
        if b.attn is not None and b.attn.kv_lora_rank is None:
            return dataclasses.replace(
                b, attn=dataclasses.replace(b.attn, kv_quant=True)
            )
        return b

    m = arch.model
    model = dataclasses.replace(
        m,
        unit=tuple(fix_block(b) for b in m.unit),
        prologue=tuple(fix_block(b) for b in m.prologue),
        epilogue=tuple(fix_block(b) for b in m.epilogue),
        shared=tuple(fix_block(b) for b in m.shared),
    )
    return dataclasses.replace(arch, model=model)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    model: ModelConfig
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    sub_quadratic: bool = False # eligible for long_500k (DESIGN.md §5)
    source: str = ""
    notes: str = ""

    def cells(self) -> list[str]:
        out = []
        for name, cell in SHAPES.items():
            if name == "long_500k" and not self.sub_quadratic:
                continue  # documented skip: pure full-attention archs
            out.append(name)
        return out
