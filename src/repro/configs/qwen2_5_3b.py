"""qwen2.5-3b [dense]: 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936 — GQA, QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""

from repro.configs.common import ArchConfig
from repro.models.attention import AttnConfig
from repro.models.blocks import BlockCfg
from repro.models.lm import ModelConfig


def build(n_layers=36, d_model=2048, n_heads=16, n_kv=2, d_ff=11008,
          vocab=151936) -> ArchConfig:
    attn = AttnConfig(
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=128, qkv_bias=True, rope_theta=1e6,
    )
    model = ModelConfig(
        name="qwen2.5-3b", d_model=d_model, vocab=vocab,
        unit=(BlockCfg("attn_mlp", attn=attn, d_ff=d_ff),),
        n_repeats=n_layers,
    )
    return ArchConfig(
        model=model, family="dense", sub_quadratic=False,
        source="hf:Qwen/Qwen2.5-3B",
        notes="kv=2 < model axis: KV heads replicate under TP; Q heads shard.",
    )


def config() -> ArchConfig:
    return build()


def reduced() -> ArchConfig:
    return build(n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=160, vocab=512)
