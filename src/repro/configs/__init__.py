"""Architecture registry: ``--arch <id>`` resolution.

10 assigned architectures (exact published configs) + the paper-technique
kanformer. Each module exposes ``config()`` (full) and ``reduced()`` (smoke).
"""

import importlib

ARCHS = {
    "zamba2-1.2b": "zamba2_1_2b",
    "musicgen-large": "musicgen_large",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "gemma3-12b": "gemma3_12b",
    "qwen1.5-32b": "qwen1_5_32b",
    "qwen2.5-3b": "qwen2_5_3b",
    "paligemma-3b": "paligemma_3b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "deepseek-v2-lite": "deepseek_v2_lite",
    "xlstm-1.3b": "xlstm_1_3b",
    "kanformer-100m": "kanformer_100m",
}

ASSIGNED = [a for a in ARCHS if a != "kanformer-100m"]


def _module(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[name]}")


def get_config(name: str):
    return _module(name).config()


def get_reduced(name: str):
    return _module(name).reduced()


def list_configs():
    return list(ARCHS)
