"""kanformer-100m — the paper's technique as a first-class LM feature.

A ~100M decoder LM whose FFN sublayers are B-spline KAN layers (G=5, P=3,
the paper's Fig-7 setting). This is the end-to-end training/serving target
for the KAN-SAs datapath (fused kernel / int8 LUT path) and one extra
dry-run cell beyond the 10 assigned architectures."""

from repro.configs.common import ArchConfig
from repro.core.bspline import SplineGrid
from repro.models.attention import AttnConfig
from repro.models.blocks import BlockCfg
from repro.models.lm import ModelConfig


def build(n_layers=8, d_model=512, n_heads=8, n_kv=8, kan_ff=1024,
          vocab=32000, G=5, P=3) -> ArchConfig:
    attn = AttnConfig(
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=d_model // n_heads,
    )
    grid = SplineGrid(-1.0, 1.0, G, P)
    model = ModelConfig(
        name="kanformer-100m", d_model=d_model, vocab=vocab,
        unit=(BlockCfg("attn_kan", attn=attn, kan_grid=grid, kan_ff=kan_ff),),
        n_repeats=n_layers,
    )
    return ArchConfig(
        model=model, family="kan", sub_quadratic=False,
        source="this work (paper technique integration)",
        notes="KAN-FFN: (G+P)x coefficient axis on both FFN GEMMs; the "
              "fused kernel keeps B out of HBM (paper SecIII-A).",
    )


def config() -> ArchConfig:
    return build()


def reduced() -> ArchConfig:
    return build(n_layers=2, d_model=64, n_heads=4, n_kv=4, kan_ff=96,
                 vocab=512, G=5, P=3)
