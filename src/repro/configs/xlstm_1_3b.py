"""xlstm-1.3b [ssm]: 48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304 —
sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified]

48 blocks at a 7:1 mLSTM:sLSTM ratio (xLSTM[7:1]); d_ff=0 means no separate
FFN sublayer — blocks carry their own pf=2 up/down projections."""

from repro.configs.common import ArchConfig
from repro.models.blocks import BlockCfg
from repro.models.lm import ModelConfig
from repro.models.xlstm import XLSTMConfig


def build(n_repeats=6, mlstm_per_unit=7, d_model=2048, n_heads=4,
          vocab=50304) -> ArchConfig:
    xc = XLSTMConfig(d_model=d_model, n_heads=n_heads)
    unit = tuple(
        [BlockCfg("mlstm", xlstm=xc)] * mlstm_per_unit
        + [BlockCfg("slstm", xlstm=xc)]
    )
    model = ModelConfig(
        name="xlstm-1.3b", d_model=d_model, vocab=vocab,
        unit=unit, n_repeats=n_repeats,
    )
    return ArchConfig(
        model=model, family="ssm", sub_quadratic=True,
        source="arXiv:2405.04517 (unverified tier)",
        notes="O(1) decode state; recurrent scan is the paper-faithful "
              "baseline — the chunked-parallel mLSTM is a §Perf item.",
    )


def config() -> ArchConfig:
    return build()


def reduced() -> ArchConfig:
    return build(n_repeats=1, mlstm_per_unit=2, d_model=64, n_heads=2, vocab=512)
