"""qwen1.5-32b [dense]: 64L d_model=5120 40H (GQA kv=40) d_ff=27392
vocab=152064 — QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""

from repro.configs.common import ArchConfig
from repro.models.attention import AttnConfig
from repro.models.blocks import BlockCfg
from repro.models.lm import ModelConfig


def build(n_layers=64, d_model=5120, n_heads=40, n_kv=40, d_ff=27392,
          vocab=152064) -> ArchConfig:
    attn = AttnConfig(
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=d_model // n_heads, qkv_bias=True,
    )
    model = ModelConfig(
        name="qwen1.5-32b", d_model=d_model, vocab=vocab,
        unit=(BlockCfg("attn_mlp", attn=attn, d_ff=d_ff),),
        n_repeats=n_layers,
    )
    return ArchConfig(
        model=model, family="dense", sub_quadratic=False,
        source="hf:Qwen/Qwen1.5-32B",
        notes="40 heads is not divisible by model=16: the sharding rules "
              "fall back to 8-way head sharding via ('model' subset) -> "
              "replication; see dist/sharding.py.",
    )


def config() -> ArchConfig:
    return build()


def reduced() -> ArchConfig:
    return build(n_layers=2, d_model=80, n_heads=5, n_kv=5, d_ff=192, vocab=512)
