"""qwen1.5-0.5b [dense]: 24L d_model=1024 16H (GQA kv=16) d_ff=2816
vocab=151936 — QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""

from repro.configs.common import ArchConfig
from repro.models.attention import AttnConfig
from repro.models.blocks import BlockCfg
from repro.models.lm import ModelConfig


def build(n_layers=24, d_model=1024, n_heads=16, n_kv=16, d_ff=2816,
          vocab=151936) -> ArchConfig:
    attn = AttnConfig(
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=d_model // n_heads, qkv_bias=True,
    )
    model = ModelConfig(
        name="qwen1.5-0.5b", d_model=d_model, vocab=vocab,
        unit=(BlockCfg("attn_mlp", attn=attn, d_ff=d_ff),),
        n_repeats=n_layers,
    )
    return ArchConfig(model=model, family="dense", sub_quadratic=False,
                      source="hf:Qwen/Qwen1.5-0.5B")


def config() -> ArchConfig:
    return build()


def reduced() -> ArchConfig:
    return build(n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=512)
